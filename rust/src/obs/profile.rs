//! Trace profiling and cost-model attribution — the *consumption* side
//! of the observability layer.
//!
//! PR 7 made the system emit structured telemetry (JSONL span traces,
//! counters, histograms); this module makes that telemetry answer the
//! paper's question. The paper's argument is a cost model — EP on
//! compactly supported covariances wins because per-sweep work scales
//! with `nnz(L)`, not `n²` — so a profile here is not just a flame
//! graph: it aggregates a drained trace into per-phase inclusive /
//! exclusive wall time, flop throughput for the factorization waves,
//! pool utilization and imbalance, a critical-path analysis over the
//! factor's wave barriers, and a **cost-model attribution table** that
//! divides each phase's measured nanoseconds by its predicted work units
//! (`flops` for the factor and Takahashi passes, `nnz(L)` per EP sweep,
//! batch items for serving) so a regression shows up as a drifting
//! ns-per-unit instead of an unexplained total.
//!
//! Everything is std-only: [`Json`] is a minimal recursive-descent JSON
//! parser for the trace schema (`obs::flush` span lines and the metrics
//! exporter's snapshot lines), [`parse_trace`] splits a JSONL file into
//! the two event kinds, [`Profile::from_trace`] aggregates, and
//! [`Profile::render_text`] / [`Profile::render_json`] feed the
//! `csgp trace analyze` subcommand. [`diff`] compares two profiles
//! phase-by-phase for `csgp trace diff`, flagging phases whose
//! ns-per-unit ratio drifts beyond a tolerance — the CI-facing answer to
//! "did this PR regress a stage or just move time around?".

use std::collections::HashMap;
use std::fmt::Write as _;
use std::time::Duration;

use crate::bench::fmt_duration;

// ---------------------------------------------------------------------------
// Minimal JSON.
// ---------------------------------------------------------------------------

/// A parsed JSON value. Object fields keep insertion order (the trace
/// schema is small; no hashing needed).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one complete JSON document (no trailing garbage).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = JsonParser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.is_finite() => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct JsonParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl JsonParser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(value)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{text}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("truncated \\u escape".to_string());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|e| e.to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| format!("bad \\u escape: {e}"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => return Err(format!("bad escape '\\{}'", c as char)),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Trace events.
// ---------------------------------------------------------------------------

/// One span line from a trace file (the serialized form of
/// [`super::SpanEvent`], with owned strings).
#[derive(Clone, Debug)]
pub struct SpanRec {
    pub name: String,
    pub tid: u64,
    pub id: u64,
    /// 0 = root (`"parent": null` in the JSONL).
    pub parent: u64,
    pub t0_ns: u64,
    pub t1_ns: u64,
    pub fields: Vec<(String, Json)>,
}

impl SpanRec {
    pub fn dur_ns(&self) -> u64 {
        self.t1_ns.saturating_sub(self.t0_ns)
    }

    pub fn field_f64(&self, key: &str) -> Option<f64> {
        self.fields.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.as_f64())
    }

    pub fn field_u64(&self, key: &str) -> Option<u64> {
        self.fields.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.as_u64())
    }

    pub fn field_bool(&self, key: &str) -> Option<bool> {
        self.fields.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.as_bool())
    }

    pub fn field_str(&self, key: &str) -> Option<&str> {
        self.fields.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.as_str())
    }
}

/// One metrics-exporter snapshot line (`"ev":"metrics"`, see
/// `coordinator::service::MetricsExporter`).
#[derive(Clone, Debug, Default)]
pub struct MetricsRec {
    pub seq: u64,
    /// Monotone nanoseconds since the emitting process's trace epoch.
    pub t_ns: u64,
    pub in_flight: u64,
    pub requests: u64,
    pub rejected: u64,
    pub request_p50_ns: Option<u64>,
    pub request_p99_ns: Option<u64>,
    /// The full counter snapshot at this instant.
    pub counters: Vec<(String, u64)>,
}

/// A parsed trace file: span events, metrics snapshots, and a count of
/// lines that were valid JSON but neither event kind.
#[derive(Clone, Debug, Default)]
pub struct TraceData {
    pub spans: Vec<SpanRec>,
    pub metrics: Vec<MetricsRec>,
    pub skipped: usize,
}

/// Parse a JSONL trace (span lines, metrics lines, or a mix — the
/// analyzer accepts both `--trace` output and `serve --metrics` output).
/// Blank lines are ignored; malformed JSON is an error naming the line.
pub fn parse_trace(text: &str) -> Result<TraceData, String> {
    let mut data = TraceData::default();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        match v.get("ev").and_then(Json::as_str) {
            Some("span") => {
                let req_u64 = |key: &str| {
                    v.get(key)
                        .and_then(Json::as_u64)
                        .ok_or_else(|| format!("line {}: span missing '{key}'", lineno + 1))
                };
                let fields = match v.get("fields") {
                    Some(Json::Obj(f)) => f.clone(),
                    _ => Vec::new(),
                };
                data.spans.push(SpanRec {
                    name: v
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("line {}: span missing 'name'", lineno + 1))?
                        .to_string(),
                    tid: req_u64("tid")?,
                    id: req_u64("id")?,
                    parent: v.get("parent").and_then(Json::as_u64).unwrap_or(0),
                    t0_ns: req_u64("t0_ns")?,
                    t1_ns: req_u64("t1_ns")?,
                    fields,
                });
            }
            Some("metrics") => {
                let u = |key: &str| v.get(key).and_then(Json::as_u64);
                let counters = match v.get("counters") {
                    Some(Json::Obj(f)) => f
                        .iter()
                        .filter_map(|(k, x)| x.as_u64().map(|n| (k.clone(), n)))
                        .collect(),
                    _ => Vec::new(),
                };
                data.metrics.push(MetricsRec {
                    seq: u("seq").unwrap_or(data.metrics.len() as u64),
                    t_ns: u("t_ns")
                        .ok_or_else(|| format!("line {}: metrics missing 't_ns'", lineno + 1))?,
                    in_flight: u("in_flight").unwrap_or(0),
                    requests: u("requests").unwrap_or(0),
                    rejected: u("rejected").unwrap_or(0),
                    request_p50_ns: u("request_p50_ns"),
                    request_p99_ns: u("request_p99_ns"),
                    counters,
                });
            }
            _ => data.skipped += 1,
        }
    }
    Ok(data)
}

// ---------------------------------------------------------------------------
// Profile aggregation.
// ---------------------------------------------------------------------------

/// Per-span-name aggregate: inclusive time (span enter→exit) and
/// exclusive time (inclusive minus the inclusive time of direct
/// children), so a phase table sums to wall time without double counting
/// nesting.
#[derive(Clone, Debug)]
pub struct PhaseStat {
    pub name: String,
    pub count: u64,
    pub inclusive_ns: u64,
    pub exclusive_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
}

/// A factor instance whose ns-per-flop is an outlier against the run's
/// median — the within-run drift flag (a jitter-retry storm, a cold page
/// wave, a pool stall show up here before they show up in totals).
#[derive(Clone, Debug)]
pub struct FactorOutlier {
    pub span_id: u64,
    pub ns: u64,
    pub flops: u64,
    pub ratio_vs_median: f64,
}

/// Aggregated factorization profile: throughput, wave critical path and
/// the parallel headroom it implies.
#[derive(Clone, Debug)]
pub struct FactorProfile {
    pub count: u64,
    pub total_ns: u64,
    pub flops: u64,
    /// Padded `nnz(L)` (max over factor spans; the pattern is fixed per
    /// run, so max == the run's value).
    pub nnz: u64,
    pub waves: u64,
    /// Lower bound on factor wall time given the wave barriers: the sum
    /// over waves of the longest participant's busy time (wave duration
    /// when a wave ran inline).
    pub critical_path_ns: u64,
    /// Total participant busy time — the serial-equivalent work.
    pub busy_ns: u64,
    pub outliers: Vec<FactorOutlier>,
}

impl FactorProfile {
    /// flops per second over measured factor wall time.
    pub fn flops_per_s(&self) -> f64 {
        if self.total_ns == 0 {
            0.0
        } else {
            self.flops as f64 / (self.total_ns as f64 * 1e-9)
        }
    }

    /// Speedup actually achieved over running every chunk serially.
    pub fn achieved_parallelism(&self) -> f64 {
        if self.total_ns == 0 {
            0.0
        } else {
            self.busy_ns as f64 / self.total_ns as f64
        }
    }

    /// Upper bound on that speedup given the wave barriers.
    pub fn max_parallelism(&self) -> f64 {
        if self.critical_path_ns == 0 {
            0.0
        } else {
            self.busy_ns as f64 / self.critical_path_ns as f64
        }
    }
}

/// Pool behaviour reconstructed from `par.worker` spans.
#[derive(Clone, Debug)]
pub struct PoolProfile {
    pub worker_spans: u64,
    pub chunks: u64,
    pub stolen_spans: u64,
    pub busy_ns: u64,
    /// Sum of worker span durations (busy + steal-loop overhead + waiting
    /// for the last chunk grab).
    pub span_ns: u64,
    pub regions: u64,
    /// Worst region's max-participant-busy over mean-participant-busy,
    /// in permille (1000 = perfectly balanced).
    pub imbalance_max_permille: u64,
}

impl PoolProfile {
    /// Fraction of worker span time spent inside chunk bodies.
    pub fn utilization(&self) -> f64 {
        if self.span_ns == 0 {
            0.0
        } else {
            self.busy_ns as f64 / self.span_ns as f64
        }
    }
}

/// EP convergence trajectory summarized from `ep.sweep` spans.
#[derive(Clone, Debug)]
pub struct EpProfile {
    pub sweeps: u64,
    pub backends: Vec<String>,
    pub final_dlogz: Option<f64>,
    pub final_max_site_delta: Option<f64>,
    pub rollbacks: u64,
    pub skipped_sites: u64,
}

/// One row of the cost-model attribution table: a phase's measured time
/// divided by its predicted work units, per the ARCHITECTURE.md per-sweep
/// cost model. Comparable across runs of the *same* phase (that is what
/// [`diff`] does); not across phases (the units differ).
#[derive(Clone, Debug)]
pub struct CostRow {
    pub phase: String,
    /// What a "unit" is for this phase ("flop", "nnz·sweep", "item").
    pub unit: &'static str,
    pub measured_ns: u64,
    pub units: f64,
    pub ns_per_unit: f64,
    pub note: String,
}

/// Metrics-exporter stream summary (`serve --metrics` round-trip).
#[derive(Clone, Debug)]
pub struct MetricsProfile {
    pub snapshots: u64,
    /// Timestamps strictly non-decreasing in file order.
    pub monotone: bool,
    pub span_ns: u64,
    pub last_in_flight: u64,
    pub requests_delta: u64,
    pub rejected_delta: u64,
    pub last_request_p50_ns: Option<u64>,
    pub last_request_p99_ns: Option<u64>,
    /// last − first per counter, nonzero entries only.
    pub counter_deltas: Vec<(String, u64)>,
}

/// The aggregated profile of one trace.
#[derive(Clone, Debug)]
pub struct Profile {
    pub spans: u64,
    /// Spans whose parent id never appeared (dropped buffers, partial
    /// file) — treated as roots.
    pub orphans: u64,
    pub wall_ns: u64,
    /// Sorted by inclusive time, descending.
    pub phases: Vec<PhaseStat>,
    pub factor: Option<FactorProfile>,
    pub pool: Option<PoolProfile>,
    pub ep: Option<EpProfile>,
    pub cost: Vec<CostRow>,
    pub metrics: Option<MetricsProfile>,
}

/// Instances slower than this multiple of the median ns-per-flop are
/// flagged as within-run drift.
const OUTLIER_RATIO: f64 = 2.0;

impl Profile {
    pub fn from_trace(data: &TraceData) -> Profile {
        let spans = &data.spans;
        let mut index: HashMap<u64, usize> = HashMap::with_capacity(spans.len());
        for (i, s) in spans.iter().enumerate() {
            index.insert(s.id, i);
        }
        // direct-children inclusive sums + child lists (for factor waves)
        let mut child_incl = vec![0u64; spans.len()];
        let mut children: HashMap<u64, Vec<usize>> = HashMap::new();
        let mut orphans = 0u64;
        for (i, s) in spans.iter().enumerate() {
            if s.parent == 0 {
                continue;
            }
            match index.get(&s.parent) {
                Some(&pi) => {
                    child_incl[pi] += s.dur_ns();
                    children.entry(s.parent).or_default().push(i);
                }
                None => orphans += 1,
            }
        }

        // per-phase table
        let mut phase_map: HashMap<&str, PhaseStat> = HashMap::new();
        for (i, s) in spans.iter().enumerate() {
            let dur = s.dur_ns();
            let excl = dur.saturating_sub(child_incl[i]);
            let e = phase_map.entry(&s.name).or_insert_with(|| PhaseStat {
                name: s.name.clone(),
                count: 0,
                inclusive_ns: 0,
                exclusive_ns: 0,
                min_ns: u64::MAX,
                max_ns: 0,
            });
            e.count += 1;
            e.inclusive_ns += dur;
            e.exclusive_ns += excl;
            e.min_ns = e.min_ns.min(dur);
            e.max_ns = e.max_ns.max(dur);
        }
        let mut phases: Vec<PhaseStat> = phase_map.into_values().collect();
        phases.sort_by(|a, b| b.inclusive_ns.cmp(&a.inclusive_ns).then(a.name.cmp(&b.name)));

        let wall_ns = {
            let t0 = spans.iter().map(|s| s.t0_ns).min();
            let t1 = spans.iter().map(|s| s.t1_ns).max();
            match (t0, t1) {
                (Some(a), Some(b)) => b.saturating_sub(a),
                _ => data
                    .metrics
                    .last()
                    .zip(data.metrics.first())
                    .map(|(l, f)| l.t_ns.saturating_sub(f.t_ns))
                    .unwrap_or(0),
            }
        };

        let factor = Self::factor_profile(spans, &children);
        let pool = Self::pool_profile(spans);
        let ep = Self::ep_profile(spans);
        let cost = Self::cost_rows(&phases, factor.as_ref());
        let metrics = Self::metrics_profile(&data.metrics);

        Profile {
            spans: spans.len() as u64,
            orphans,
            wall_ns,
            phases,
            factor,
            pool,
            ep,
            cost,
            metrics,
        }
    }

    fn factor_profile(
        spans: &[SpanRec],
        children: &HashMap<u64, Vec<usize>>,
    ) -> Option<FactorProfile> {
        let mut out = FactorProfile {
            count: 0,
            total_ns: 0,
            flops: 0,
            nnz: 0,
            waves: 0,
            critical_path_ns: 0,
            busy_ns: 0,
            outliers: Vec::new(),
        };
        // (span id, ns, flops) per factor instance for outlier flagging
        let mut instances: Vec<(u64, u64, u64)> = Vec::new();
        for f in spans.iter().filter(|s| s.name == "factor") {
            out.count += 1;
            out.total_ns += f.dur_ns();
            out.nnz = out.nnz.max(f.field_u64("nnz").unwrap_or(0));
            let mut f_flops = 0u64;
            for &wi in children.get(&f.id).map(Vec::as_slice).unwrap_or(&[]) {
                let w = &spans[wi];
                if w.name != "factor.wave" {
                    continue;
                }
                out.waves += 1;
                f_flops += w.field_u64("flops").unwrap_or(0);
                // critical path: the longest participant of this wave
                // (the wave itself when it ran inline, no workers)
                let mut wave_busy = 0u64;
                let mut wave_crit = 0u64;
                for &pi in children.get(&w.id).map(Vec::as_slice).unwrap_or(&[]) {
                    let p = &spans[pi];
                    if p.name != "par.worker" {
                        continue;
                    }
                    let busy = p.field_u64("busy_ns").unwrap_or(p.dur_ns());
                    wave_busy += busy;
                    wave_crit = wave_crit.max(busy);
                }
                if wave_crit == 0 {
                    wave_crit = w.dur_ns();
                    wave_busy = w.dur_ns();
                }
                out.critical_path_ns += wave_crit;
                out.busy_ns += wave_busy;
            }
            out.flops += f_flops;
            if f_flops > 0 {
                instances.push((f.id, f.dur_ns(), f_flops));
            }
        }
        if out.count == 0 {
            return None;
        }
        // within-run drift: instances whose ns/flop exceeds 2x the median
        if instances.len() >= 2 {
            let mut ratios: Vec<f64> =
                instances.iter().map(|&(_, ns, fl)| ns as f64 / fl as f64).collect();
            ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = ratios[ratios.len() / 2];
            if median > 0.0 {
                for &(id, ns, fl) in &instances {
                    let r = (ns as f64 / fl as f64) / median;
                    if r > OUTLIER_RATIO {
                        out.outliers.push(FactorOutlier {
                            span_id: id,
                            ns,
                            flops: fl,
                            ratio_vs_median: r,
                        });
                    }
                }
                out.outliers.sort_by(|a, b| {
                    b.ratio_vs_median.partial_cmp(&a.ratio_vs_median).unwrap()
                });
            }
        }
        Some(out)
    }

    fn pool_profile(spans: &[SpanRec]) -> Option<PoolProfile> {
        let mut out = PoolProfile {
            worker_spans: 0,
            chunks: 0,
            stolen_spans: 0,
            busy_ns: 0,
            span_ns: 0,
            regions: 0,
            imbalance_max_permille: 0,
        };
        // region = the issuing span a worker parented under
        let mut regions: HashMap<u64, Vec<u64>> = HashMap::new();
        for w in spans.iter().filter(|s| s.name == "par.worker") {
            out.worker_spans += 1;
            out.chunks += w.field_u64("chunks").unwrap_or(0);
            if w.field_bool("stolen").unwrap_or(false) {
                out.stolen_spans += 1;
            }
            let busy = w.field_u64("busy_ns").unwrap_or(0);
            out.busy_ns += busy;
            out.span_ns += w.dur_ns();
            regions.entry(w.parent).or_default().push(busy);
        }
        if out.worker_spans == 0 {
            return None;
        }
        out.regions = regions.len() as u64;
        for busys in regions.values() {
            let max = busys.iter().copied().max().unwrap_or(0) as f64;
            let mean = busys.iter().sum::<u64>() as f64 / busys.len() as f64;
            if mean > 0.0 {
                out.imbalance_max_permille =
                    out.imbalance_max_permille.max((max / mean * 1000.0) as u64);
            }
        }
        Some(out)
    }

    fn ep_profile(spans: &[SpanRec]) -> Option<EpProfile> {
        let mut sweeps: Vec<&SpanRec> = spans.iter().filter(|s| s.name == "ep.sweep").collect();
        if sweeps.is_empty() {
            return None;
        }
        sweeps.sort_by_key(|s| s.t0_ns);
        let mut backends: Vec<String> = Vec::new();
        let mut rollbacks = 0u64;
        let mut skipped = 0u64;
        for s in &sweeps {
            if let Some(b) = s.field_str("backend") {
                if !backends.iter().any(|x| x == b) {
                    backends.push(b.to_string());
                }
            }
            if s.field_bool("rolled_back").unwrap_or(false) {
                rollbacks += 1;
            }
            skipped += s.field_u64("skipped_sites").unwrap_or(0);
        }
        let last = sweeps.last().unwrap();
        Some(EpProfile {
            sweeps: sweeps.len() as u64,
            backends,
            final_dlogz: last.field_f64("dlogz"),
            final_max_site_delta: last.field_f64("max_site_delta"),
            rollbacks,
            skipped_sites: skipped,
        })
    }

    /// The attribution table. Per the ARCHITECTURE.md cost model:
    /// factorization and Takahashi work is counted in flops (exact, from
    /// the wave instrumentation), per-sweep EP work scales with `nnz(L)`
    /// (the paper's core claim), and service batches scale with items.
    fn cost_rows(phases: &[PhaseStat], factor: Option<&FactorProfile>) -> Vec<CostRow> {
        let mut rows = Vec::new();
        let phase = |name: &str| phases.iter().find(|p| p.name == name);
        if let Some(f) = factor {
            if f.flops > 0 && f.total_ns > 0 {
                let note = if f.outliers.is_empty() {
                    String::new()
                } else {
                    format!(
                        "{} instance(s) > {OUTLIER_RATIO:.0}x median ns/flop (worst {:.1}x)",
                        f.outliers.len(),
                        f.outliers[0].ratio_vs_median
                    )
                };
                rows.push(CostRow {
                    phase: "factor".to_string(),
                    unit: "flop",
                    measured_ns: f.total_ns,
                    units: f.flops as f64,
                    ns_per_unit: f.total_ns as f64 / f.flops as f64,
                    note,
                });
            }
            if let Some(p) = phase("takahashi") {
                // same dense-panel traffic over the same pattern as the
                // factor, so the factor's mean flop count per pass is the
                // model (the wave fields live on the factor spans)
                let per_pass = f.flops as f64 / f.count.max(1) as f64;
                let units = per_pass * p.count as f64;
                if units > 0.0 && p.inclusive_ns > 0 {
                    rows.push(CostRow {
                        phase: "takahashi".to_string(),
                        unit: "flop",
                        measured_ns: p.inclusive_ns,
                        units,
                        ns_per_unit: p.inclusive_ns as f64 / units,
                        note: "flops modeled from factor panel work".to_string(),
                    });
                }
            }
            if let Some(p) = phase("ep.sweep") {
                // the paper's claim: per-sweep work (site visits, solves,
                // marginals — everything except the nested factor, hence
                // exclusive time) is O(nnz(L))
                let units = f.nnz as f64 * p.count as f64;
                if units > 0.0 && p.exclusive_ns > 0 {
                    rows.push(CostRow {
                        phase: "ep.sweep".to_string(),
                        unit: "nnz·sweep",
                        measured_ns: p.exclusive_ns,
                        units,
                        ns_per_unit: p.exclusive_ns as f64 / units,
                        note: "exclusive of the nested factor".to_string(),
                    });
                }
            }
        }
        if let Some(p) = phase("svc.batch") {
            // units come from the per-span `size` field; the phase table
            // has no field sums, so this row is only emitted when the
            // factor path isn't the story (serving traces)
            rows.push(CostRow {
                phase: "svc.batch".to_string(),
                unit: "batch",
                measured_ns: p.inclusive_ns,
                units: p.count as f64,
                ns_per_unit: p.inclusive_ns as f64 / p.count.max(1) as f64,
                note: String::new(),
            });
        }
        rows
    }

    fn metrics_profile(metrics: &[MetricsRec]) -> Option<MetricsProfile> {
        let (first, last) = (metrics.first()?, metrics.last()?);
        let monotone = metrics.windows(2).all(|w| w[0].t_ns <= w[1].t_ns);
        let first_counters: HashMap<&str, u64> =
            first.counters.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        let mut counter_deltas: Vec<(String, u64)> = last
            .counters
            .iter()
            .map(|(k, v)| {
                let base = first_counters.get(k.as_str()).copied().unwrap_or(0);
                (k.clone(), v.saturating_sub(base))
            })
            .filter(|(_, d)| *d > 0)
            .collect();
        counter_deltas.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        Some(MetricsProfile {
            snapshots: metrics.len() as u64,
            monotone,
            span_ns: last.t_ns.saturating_sub(first.t_ns),
            last_in_flight: last.in_flight,
            requests_delta: last.requests.saturating_sub(first.requests),
            rejected_delta: last.rejected.saturating_sub(first.rejected),
            last_request_p50_ns: last.request_p50_ns,
            last_request_p99_ns: last.request_p99_ns,
            counter_deltas,
        })
    }

    // -- rendering ---------------------------------------------------------

    /// Human-readable report (the default `csgp trace analyze` output).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        let ns = |v: u64| fmt_duration(Duration::from_nanos(v));
        let _ = writeln!(
            out,
            "trace profile: {} spans, wall {}{}",
            self.spans,
            ns(self.wall_ns),
            if self.orphans > 0 {
                format!(" ({} orphaned spans treated as roots)", self.orphans)
            } else {
                String::new()
            }
        );
        if !self.phases.is_empty() {
            let _ = writeln!(out, "\nphases:");
            let _ = writeln!(
                out,
                "  {:<14} {:>7} {:>12} {:>12} {:>7} {:>12}",
                "phase", "count", "inclusive", "exclusive", "incl%", "max"
            );
            for p in &self.phases {
                let pct = if self.wall_ns > 0 {
                    100.0 * p.inclusive_ns as f64 / self.wall_ns as f64
                } else {
                    0.0
                };
                let _ = writeln!(
                    out,
                    "  {:<14} {:>7} {:>12} {:>12} {:>6.1}% {:>12}",
                    p.name,
                    p.count,
                    ns(p.inclusive_ns),
                    ns(p.exclusive_ns),
                    pct,
                    ns(p.max_ns)
                );
            }
        }
        if let Some(f) = &self.factor {
            let _ = writeln!(
                out,
                "\nfactor: {} refactor(s), {} over {} waves -> {} \
                 (nnz(L) = {}, critical path {} => max parallel {:.2}x, achieved {:.2}x)",
                f.count,
                fmt_flops(f.flops),
                f.waves,
                fmt_flops_per_s(f.flops_per_s()),
                f.nnz,
                ns(f.critical_path_ns),
                f.max_parallelism(),
                f.achieved_parallelism(),
            );
            for o in f.outliers.iter().take(3) {
                let _ = writeln!(
                    out,
                    "  WARNING: factor span {} ran {:.1}x the median ns/flop ({} for {})",
                    o.span_id,
                    o.ratio_vs_median,
                    ns(o.ns),
                    fmt_flops(o.flops)
                );
            }
        }
        if let Some(p) = &self.pool {
            let _ = writeln!(
                out,
                "pool: {} worker span(s) over {} region(s): {} chunks, {:.0}% utilization, \
                 {} stolen, imbalance max {} permille",
                p.worker_spans,
                p.regions,
                p.chunks,
                100.0 * p.utilization(),
                p.stolen_spans,
                p.imbalance_max_permille
            );
        }
        if let Some(e) = &self.ep {
            let _ = writeln!(
                out,
                "ep: {} sweep(s) [{}], final |dlogz| {}, max site delta {}, \
                 rollbacks {}, skipped sites {}",
                e.sweeps,
                e.backends.join(", "),
                e.final_dlogz.map(|v| format!("{:.3e}", v.abs())).unwrap_or_else(|| "-".into()),
                e.final_max_site_delta
                    .map(|v| format!("{v:.3e}"))
                    .unwrap_or_else(|| "-".into()),
                e.rollbacks,
                e.skipped_sites
            );
        }
        if !self.cost.is_empty() {
            let _ = writeln!(out, "\ncost model (measured vs predicted work units):");
            let _ = writeln!(
                out,
                "  {:<12} {:>12} {:>14} {:>12}  note",
                "phase", "measured", "units", "ns/unit"
            );
            for r in &self.cost {
                let _ = writeln!(
                    out,
                    "  {:<12} {:>12} {:>14} {:>12.4}  {}",
                    r.phase,
                    ns(r.measured_ns),
                    format!("{} {}", fmt_units(r.units), r.unit),
                    r.ns_per_unit,
                    r.note
                );
            }
        }
        if let Some(m) = &self.metrics {
            let _ = writeln!(
                out,
                "\nmetrics: {} snapshot(s) over {} (timestamps {}), last in_flight {}, \
                 +requests {}, +rejected {}{}",
                m.snapshots,
                ns(m.span_ns),
                if m.monotone { "monotone" } else { "NOT MONOTONE" },
                m.last_in_flight,
                m.requests_delta,
                m.rejected_delta,
                match (m.last_request_p50_ns, m.last_request_p99_ns) {
                    (Some(p50), Some(p99)) =>
                        format!(", request p50 {} p99 {}", ns(p50), ns(p99)),
                    _ => String::new(),
                }
            );
            if !m.counter_deltas.is_empty() {
                let deltas: Vec<String> = m
                    .counter_deltas
                    .iter()
                    .take(12)
                    .map(|(k, v)| format!("{k} +{v}"))
                    .collect();
                let _ = writeln!(out, "  counter deltas: {}", deltas.join(", "));
            }
        }
        out
    }

    /// Machine-readable report (`csgp trace analyze --json`). Stable
    /// field order; consumed by CI smokes and downstream tooling.
    pub fn render_json(&self) -> String {
        let mut o = String::new();
        o.push_str("{\n");
        let _ = write!(
            o,
            "  \"spans\": {}, \"orphans\": {}, \"wall_ns\": {},\n",
            self.spans, self.orphans, self.wall_ns
        );
        o.push_str("  \"phases\": [\n");
        for (i, p) in self.phases.iter().enumerate() {
            let _ = write!(
                o,
                "    {{\"name\": \"{}\", \"count\": {}, \"inclusive_ns\": {}, \
                 \"exclusive_ns\": {}, \"min_ns\": {}, \"max_ns\": {}}}{}\n",
                p.name,
                p.count,
                p.inclusive_ns,
                p.exclusive_ns,
                p.min_ns,
                p.max_ns,
                if i + 1 < self.phases.len() { "," } else { "" }
            );
        }
        o.push_str("  ],\n");
        match &self.factor {
            Some(f) => {
                let _ = write!(
                    o,
                    "  \"factor\": {{\"count\": {}, \"total_ns\": {}, \"flops\": {}, \
                     \"nnz\": {}, \"waves\": {}, \"critical_path_ns\": {}, \"busy_ns\": {}, \
                     \"flops_per_s\": {:.1}, \"outliers\": {}}},\n",
                    f.count,
                    f.total_ns,
                    f.flops,
                    f.nnz,
                    f.waves,
                    f.critical_path_ns,
                    f.busy_ns,
                    f.flops_per_s(),
                    f.outliers.len()
                );
            }
            None => o.push_str("  \"factor\": null,\n"),
        }
        match &self.pool {
            Some(p) => {
                let _ = write!(
                    o,
                    "  \"pool\": {{\"worker_spans\": {}, \"chunks\": {}, \"stolen_spans\": {}, \
                     \"busy_ns\": {}, \"span_ns\": {}, \"regions\": {}, \
                     \"utilization\": {:.4}, \"imbalance_max_permille\": {}}},\n",
                    p.worker_spans,
                    p.chunks,
                    p.stolen_spans,
                    p.busy_ns,
                    p.span_ns,
                    p.regions,
                    p.utilization(),
                    p.imbalance_max_permille
                );
            }
            None => o.push_str("  \"pool\": null,\n"),
        }
        match &self.ep {
            Some(e) => {
                let backends: Vec<String> =
                    e.backends.iter().map(|b| format!("\"{b}\"")).collect();
                let _ = write!(
                    o,
                    "  \"ep\": {{\"sweeps\": {}, \"backends\": [{}], \"rollbacks\": {}, \
                     \"skipped_sites\": {}}},\n",
                    e.sweeps,
                    backends.join(", "),
                    e.rollbacks,
                    e.skipped_sites
                );
            }
            None => o.push_str("  \"ep\": null,\n"),
        }
        o.push_str("  \"cost\": [\n");
        for (i, r) in self.cost.iter().enumerate() {
            let _ = write!(
                o,
                "    {{\"phase\": \"{}\", \"unit\": \"{}\", \"measured_ns\": {}, \
                 \"units\": {:.1}, \"ns_per_unit\": {:.6}, \"note\": \"{}\"}}{}\n",
                r.phase,
                r.unit,
                r.measured_ns,
                r.units,
                r.ns_per_unit,
                r.note,
                if i + 1 < self.cost.len() { "," } else { "" }
            );
        }
        o.push_str("  ],\n");
        match &self.metrics {
            Some(m) => {
                let deltas: Vec<String> = m
                    .counter_deltas
                    .iter()
                    .map(|(k, v)| format!("\"{k}\": {v}"))
                    .collect();
                let _ = write!(
                    o,
                    "  \"metrics\": {{\"snapshots\": {}, \"monotone\": {}, \"span_ns\": {}, \
                     \"last_in_flight\": {}, \"requests_delta\": {}, \"rejected_delta\": {}, \
                     \"counter_deltas\": {{{}}}}}\n",
                    m.snapshots,
                    m.monotone,
                    m.span_ns,
                    m.last_in_flight,
                    m.requests_delta,
                    m.rejected_delta,
                    deltas.join(", ")
                );
            }
            None => o.push_str("  \"metrics\": null\n"),
        }
        o.push_str("}\n");
        o
    }
}

// ---------------------------------------------------------------------------
// Diff.
// ---------------------------------------------------------------------------

/// One phase's A-vs-B comparison.
#[derive(Clone, Debug)]
pub struct PhaseDelta {
    pub name: String,
    pub a_inclusive_ns: u64,
    pub b_inclusive_ns: u64,
    /// b/a (None when the phase is missing on either side).
    pub ratio: Option<f64>,
    pub flagged: bool,
}

/// One cost-model row's ns-per-unit drift between runs.
#[derive(Clone, Debug)]
pub struct CostDelta {
    pub phase: String,
    pub unit: &'static str,
    pub a_ns_per_unit: f64,
    pub b_ns_per_unit: f64,
    pub ratio: f64,
    pub flagged: bool,
}

/// `csgp trace diff` result: per-phase wall-time deltas plus
/// cost-model-normalized drift (the latter is the regression signal —
/// ns-per-unit factors out "run B simply did more sweeps").
#[derive(Clone, Debug)]
pub struct ProfileDiff {
    pub tolerance: f64,
    pub a_wall_ns: u64,
    pub b_wall_ns: u64,
    pub phases: Vec<PhaseDelta>,
    pub cost: Vec<CostDelta>,
}

impl ProfileDiff {
    pub fn flagged(&self) -> usize {
        self.phases.iter().filter(|p| p.flagged).count()
            + self.cost.iter().filter(|c| c.flagged).count()
    }

    pub fn render_text(&self) -> String {
        let mut out = String::new();
        let ns = |v: u64| fmt_duration(Duration::from_nanos(v));
        let _ = writeln!(
            out,
            "trace diff (tolerance {:.0}%): wall {} -> {}",
            self.tolerance * 100.0,
            ns(self.a_wall_ns),
            ns(self.b_wall_ns)
        );
        let _ = writeln!(
            out,
            "  {:<14} {:>12} {:>12} {:>9}",
            "phase", "a inclusive", "b inclusive", "b/a"
        );
        for p in &self.phases {
            let ratio = match p.ratio {
                Some(r) => format!("{r:.2}x"),
                None => "-".to_string(),
            };
            let _ = writeln!(
                out,
                "  {:<14} {:>12} {:>12} {:>9}{}",
                p.name,
                ns(p.a_inclusive_ns),
                ns(p.b_inclusive_ns),
                ratio,
                if p.flagged { "  <-- drift" } else { "" }
            );
        }
        if !self.cost.is_empty() {
            let _ = writeln!(out, "cost-model drift (ns/unit, normalized for work done):");
            for c in &self.cost {
                let _ = writeln!(
                    out,
                    "  {:<14} {:>10.4} -> {:>10.4} ns/{} ({:.2}x){}",
                    c.phase,
                    c.a_ns_per_unit,
                    c.b_ns_per_unit,
                    c.unit,
                    c.ratio,
                    if c.flagged { "  <-- drift" } else { "" }
                );
            }
        }
        let flagged = self.flagged();
        let _ = writeln!(
            out,
            "{}",
            if flagged == 0 {
                "no drift beyond tolerance".to_string()
            } else {
                format!("{flagged} phase(s) drifted beyond tolerance")
            }
        );
        out
    }

    pub fn render_json(&self) -> String {
        let mut o = String::new();
        o.push_str("{\n");
        let _ = write!(
            o,
            "  \"tolerance\": {}, \"a_wall_ns\": {}, \"b_wall_ns\": {}, \"flagged\": {},\n",
            self.tolerance,
            self.a_wall_ns,
            self.b_wall_ns,
            self.flagged()
        );
        o.push_str("  \"phases\": [\n");
        for (i, p) in self.phases.iter().enumerate() {
            let ratio = match p.ratio {
                Some(r) => format!("{r:.6}"),
                None => "null".to_string(),
            };
            let _ = write!(
                o,
                "    {{\"name\": \"{}\", \"a_inclusive_ns\": {}, \"b_inclusive_ns\": {}, \
                 \"ratio\": {}, \"flagged\": {}}}{}\n",
                p.name,
                p.a_inclusive_ns,
                p.b_inclusive_ns,
                ratio,
                p.flagged,
                if i + 1 < self.phases.len() { "," } else { "" }
            );
        }
        o.push_str("  ],\n");
        o.push_str("  \"cost\": [\n");
        for (i, c) in self.cost.iter().enumerate() {
            let _ = write!(
                o,
                "    {{\"phase\": \"{}\", \"unit\": \"{}\", \"a_ns_per_unit\": {:.6}, \
                 \"b_ns_per_unit\": {:.6}, \"ratio\": {:.6}, \"flagged\": {}}}{}\n",
                c.phase,
                c.unit,
                c.a_ns_per_unit,
                c.b_ns_per_unit,
                c.ratio,
                c.flagged,
                if i + 1 < self.cost.len() { "," } else { "" }
            );
        }
        o.push_str("  ]\n}\n");
        o
    }
}

/// Compare two profiles. A phase or cost row is flagged when its b/a
/// ratio exceeds `1 + tolerance` (slower) — one-sided, like the bench
/// gate: getting faster is not a regression.
pub fn diff(a: &Profile, b: &Profile, tolerance: f64) -> ProfileDiff {
    let mut names: Vec<&str> = a.phases.iter().map(|p| p.name.as_str()).collect();
    for p in &b.phases {
        if !names.contains(&p.name.as_str()) {
            names.push(&p.name);
        }
    }
    let phases = names
        .iter()
        .map(|&name| {
            let pa = a.phases.iter().find(|p| p.name == name);
            let pb = b.phases.iter().find(|p| p.name == name);
            let a_ns = pa.map_or(0, |p| p.inclusive_ns);
            let b_ns = pb.map_or(0, |p| p.inclusive_ns);
            let ratio = match (pa, pb) {
                (Some(x), Some(_)) if x.inclusive_ns > 0 => {
                    Some(b_ns as f64 / x.inclusive_ns as f64)
                }
                _ => None,
            };
            PhaseDelta {
                name: name.to_string(),
                a_inclusive_ns: a_ns,
                b_inclusive_ns: b_ns,
                ratio,
                // missing-on-one-side is structural change, not drift;
                // wall-time ratios are only advisory (cost rows below are
                // the normalized signal), but still flagged so a doubled
                // phase cannot hide
                flagged: ratio.is_some_and(|r| r > 1.0 + tolerance),
            }
        })
        .collect();
    let cost = a
        .cost
        .iter()
        .filter_map(|ra| {
            let rb = b.cost.iter().find(|r| r.phase == ra.phase)?;
            if ra.ns_per_unit <= 0.0 {
                return None;
            }
            let ratio = rb.ns_per_unit / ra.ns_per_unit;
            Some(CostDelta {
                phase: ra.phase.clone(),
                unit: ra.unit,
                a_ns_per_unit: ra.ns_per_unit,
                b_ns_per_unit: rb.ns_per_unit,
                ratio,
                flagged: ratio > 1.0 + tolerance,
            })
        })
        .collect();
    ProfileDiff { tolerance, a_wall_ns: a.wall_ns, b_wall_ns: b.wall_ns, phases, cost }
}

// ---------------------------------------------------------------------------
// Formatting helpers.
// ---------------------------------------------------------------------------

fn fmt_flops(f: u64) -> String {
    fmt_scaled(f as f64, "flop")
}

fn fmt_flops_per_s(f: f64) -> String {
    fmt_scaled(f, "flop/s")
}

fn fmt_units(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}k", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

fn fmt_scaled(v: f64, unit: &str) -> String {
    if v >= 1e9 {
        format!("{:.2} G{unit}", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2} M{unit}", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2} k{unit}", v / 1e3)
    } else {
        format!("{v:.0} {unit}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_parses_the_trace_schema() {
        let line = "{\"ev\":\"span\",\"name\":\"ep.sweep\",\"tid\":3,\"id\":17,\
                    \"parent\":null,\"t0_ns\":5,\"t1_ns\":9,\"fields\":{\"sweep\":2,\
                    \"dlogz\":null,\"backend\":\"sparse\",\"damped\":true,\"delta\":0.25}}";
        let v = Json::parse(line).unwrap();
        assert_eq!(v.get("ev").and_then(Json::as_str), Some("span"));
        assert_eq!(v.get("id").and_then(Json::as_u64), Some(17));
        assert_eq!(v.get("parent"), Some(&Json::Null));
        let fields = v.get("fields").unwrap();
        assert_eq!(fields.get("sweep").and_then(Json::as_u64), Some(2));
        assert_eq!(fields.get("dlogz"), Some(&Json::Null));
        assert_eq!(fields.get("backend").and_then(Json::as_str), Some("sparse"));
        assert_eq!(fields.get("damped").and_then(Json::as_bool), Some(true));
        assert_eq!(fields.get("delta").and_then(Json::as_f64), Some(0.25));
    }

    #[test]
    fn json_handles_escapes_arrays_and_exponents() {
        let v = Json::parse("{\"s\":\"a\\\"b\\\\c\\u0041\",\"a\":[1,-2.5,1e3],\"b\":false}")
            .unwrap();
        assert_eq!(v.get("s").and_then(Json::as_str), Some("a\"b\\cA"));
        match v.get("a") {
            Some(Json::Arr(items)) => {
                assert_eq!(items.len(), 3);
                assert_eq!(items[1].as_f64(), Some(-2.5));
                assert_eq!(items[2].as_f64(), Some(1000.0));
            }
            other => panic!("expected array, got {other:?}"),
        }
        assert_eq!(v.get("b").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} trailing").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    fn span(name: &str, id: u64, parent: u64, t0: u64, t1: u64) -> String {
        format!(
            "{{\"ev\":\"span\",\"name\":\"{name}\",\"tid\":1,\"id\":{id},\
             \"parent\":{},\"t0_ns\":{t0},\"t1_ns\":{t1},\"fields\":{{}}}}",
            if parent == 0 { "null".to_string() } else { parent.to_string() }
        )
    }

    #[test]
    fn inclusive_exclusive_accounting() {
        // root [0,100] with children [10,30] and [40,80]; grandchild [45,55]
        let text = [
            span("root", 1, 0, 0, 100),
            span("child", 2, 1, 10, 30),
            span("child", 3, 1, 40, 80),
            span("grand", 4, 3, 45, 55),
        ]
        .join("\n");
        let data = parse_trace(&text).unwrap();
        let p = Profile::from_trace(&data);
        assert_eq!(p.spans, 4);
        assert_eq!(p.orphans, 0);
        assert_eq!(p.wall_ns, 100);
        let phase = |n: &str| p.phases.iter().find(|x| x.name == n).unwrap();
        assert_eq!(phase("root").inclusive_ns, 100);
        assert_eq!(phase("root").exclusive_ns, 40); // 100 - 20 - 40
        assert_eq!(phase("child").inclusive_ns, 60);
        assert_eq!(phase("child").exclusive_ns, 50); // 20 + (40 - 10)
        assert_eq!(phase("grand").exclusive_ns, 10);
        // invariant: sum of exclusive over all phases == root inclusive
        let total_excl: u64 = p.phases.iter().map(|x| x.exclusive_ns).sum();
        assert_eq!(total_excl, 100);
    }

    #[test]
    fn orphaned_parents_are_counted_not_dropped() {
        let text = span("lost", 9, 777, 5, 15);
        let p = Profile::from_trace(&parse_trace(&text).unwrap());
        assert_eq!(p.spans, 1);
        assert_eq!(p.orphans, 1);
        assert_eq!(p.phases[0].inclusive_ns, 10);
    }

    #[test]
    fn metrics_lines_round_trip() {
        let text = "\
            {\"ev\":\"metrics\",\"seq\":0,\"t_ns\":100,\"in_flight\":1,\"requests\":10,\
             \"rejected\":0,\"request_p50_ns\":500,\"request_p99_ns\":900,\
             \"counters\":{\"ep_sweeps\":5,\"solves\":100}}\n\
            {\"ev\":\"metrics\",\"seq\":1,\"t_ns\":200,\"in_flight\":3,\"requests\":25,\
             \"rejected\":2,\"request_p50_ns\":600,\"request_p99_ns\":950,\
             \"counters\":{\"ep_sweeps\":8,\"solves\":100}}";
        let data = parse_trace(text).unwrap();
        assert_eq!(data.metrics.len(), 2);
        let p = Profile::from_trace(&data);
        let m = p.metrics.expect("metrics profile");
        assert_eq!(m.snapshots, 2);
        assert!(m.monotone);
        assert_eq!(m.span_ns, 100);
        assert_eq!(m.last_in_flight, 3);
        assert_eq!(m.requests_delta, 15);
        assert_eq!(m.rejected_delta, 2);
        assert_eq!(m.last_request_p50_ns, Some(600));
        // only the counter that moved is reported
        assert_eq!(m.counter_deltas, vec![("ep_sweeps".to_string(), 3)]);
        // and the renderers mention the stream
        assert!(p.render_text().contains("metrics: 2 snapshot(s)"));
        assert!(p.render_json().contains("\"snapshots\": 2"));
    }

    #[test]
    fn non_monotone_metrics_are_called_out() {
        let text = "{\"ev\":\"metrics\",\"t_ns\":200,\"counters\":{}}\n\
                    {\"ev\":\"metrics\",\"t_ns\":100,\"counters\":{}}";
        let p = Profile::from_trace(&parse_trace(text).unwrap());
        assert!(!p.metrics.as_ref().unwrap().monotone);
        assert!(p.render_text().contains("NOT MONOTONE"));
    }

    #[test]
    fn diff_flags_slower_phases_one_sided() {
        let mk = |scale: u64| {
            let text =
                [span("ep.sweep", 1, 0, 0, 100 * scale), span("predict", 2, 0, 0, 50)].join("\n");
            Profile::from_trace(&parse_trace(&text).unwrap())
        };
        let a = mk(1);
        let b = mk(2);
        let d = diff(&a, &b, 0.25);
        let sweep = d.phases.iter().find(|p| p.name == "ep.sweep").unwrap();
        assert!(sweep.flagged, "2x slower must be flagged at 25% tolerance");
        assert_eq!(sweep.ratio, Some(2.0));
        let predict = d.phases.iter().find(|p| p.name == "predict").unwrap();
        assert!(!predict.flagged);
        // the reverse direction (faster) is not a regression
        let d2 = diff(&b, &a, 0.25);
        assert!(!d2.phases.iter().find(|p| p.name == "ep.sweep").unwrap().flagged);
        assert!(d.render_text().contains("drift"));
        assert!(d.render_json().contains("\"flagged\": true"));
    }
}
