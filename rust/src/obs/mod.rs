//! `obs` — structured tracing, metrics, and profiling hooks.
//!
//! The paper's argument is a cost model: EP sweep time decomposed into
//! factorization, rank-one updates and marginal-variance passes. This
//! module lets the *running* system report that decomposition — and EP's
//! convergence trajectory — without a bespoke bench per question. Std
//! only, no external crates, and near-zero cost when disabled.
//!
//! Three trace modes, selected by the `CSGP_TRACE` environment variable
//! (read once, lazily) or programmatically via [`set_mode`]:
//!
//! * **Off** (`CSGP_TRACE` unset, `0`, or `off`) — every instrumentation
//!   site reduces to one relaxed atomic load and a branch. No allocation,
//!   no timestamps, no formatting.
//! * **Counters** (`1` / `counters`) — process-wide atomic counters,
//!   max-gauges and log₂-bucketed latency histograms ([`counters`]) are
//!   live; spans stay inert. Cheap enough for benches to leave on.
//! * **Full** (`2` / `full`) — counters plus structured spans: RAII
//!   enter/exit pairs with `Instant` timestamps, parent links, static
//!   names and small typed field maps, buffered per thread and drained to
//!   a JSONL sink ([`set_sink`] / [`flush`]) or to tests ([`take_events`]).
//!
//! # Span tree across the pool
//!
//! Spans record their parent from a thread-local "current span" cell, so
//! nesting on one thread needs no bookkeeping. Cross-thread edges — a
//! factorization wave fanning out chunks to pool workers — are made
//! explicit: the issuer captures [`current_span_id`] and each worker
//! installs it with [`parent_scope`] for the duration of its
//! participation, so `ep.sweep → factor → factor.wave → par.worker`
//! parents correctly even though the `par.worker` span lives on another
//! thread. Parents always close after children because `par::for_chunks`
//! joins every chunk before the issuer's span guard drops.
//!
//! # Inertness contract
//!
//! Tracing must never change results. Instrumentation only *observes*
//! (timestamps, counts, field reads); kernel selection, chunk splitting
//! and scheduling never consult obs state, and per-thread buffers mean no
//! instrumentation lock is ever contended on a hot path. The integration
//! test `tracing_modes_never_change_results_and_spans_nest` pins
//! bitwise-identical models across all three modes at pool widths 1/2/7.

pub mod counters;
pub mod hist;
pub mod profile;

pub use counters::{snapshot, summary, Snapshot};
pub use hist::Histogram;
pub use profile::{parse_trace, Profile};

use std::cell::Cell;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Mode.
// ---------------------------------------------------------------------------

/// How much the process records. See the module docs for the cost of
/// each level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum TraceMode {
    /// Nothing is recorded; every site is one relaxed load + branch.
    Off = 0,
    /// Atomic counters / gauges / histograms only.
    Counters = 1,
    /// Counters plus buffered spans.
    Full = 2,
}

const MODE_UNINIT: u8 = 0xFF;
static MODE: AtomicU8 = AtomicU8::new(MODE_UNINIT);

#[cold]
fn init_mode_from_env() -> u8 {
    let want = match std::env::var("CSGP_TRACE") {
        Ok(v) => match v.trim().to_ascii_lowercase().as_str() {
            "1" | "counters" => 1,
            "2" | "full" => 2,
            _ => 0,
        },
        Err(_) => 0,
    };
    // Racing initializers agree on the env value; an explicit `set_mode`
    // that slipped in first wins.
    let _ = MODE.compare_exchange(MODE_UNINIT, want, Ordering::Relaxed, Ordering::Relaxed);
    MODE.load(Ordering::Relaxed)
}

#[inline]
fn mode_u8() -> u8 {
    let m = MODE.load(Ordering::Relaxed);
    if m == MODE_UNINIT {
        init_mode_from_env()
    } else {
        m
    }
}

/// The current trace mode (lazily initialized from `CSGP_TRACE`).
pub fn mode() -> TraceMode {
    match mode_u8() {
        2 => TraceMode::Full,
        1 => TraceMode::Counters,
        _ => TraceMode::Off,
    }
}

/// Are counters (and histograms / gauges) live? One relaxed load.
#[inline]
pub fn counters_on() -> bool {
    mode_u8() >= TraceMode::Counters as u8
}

/// Are spans live? One relaxed load.
#[inline]
pub fn spans_on() -> bool {
    mode_u8() == TraceMode::Full as u8
}

/// Set the trace mode for the whole process (overrides `CSGP_TRACE`).
pub fn set_mode(mode: TraceMode) {
    MODE.store(mode as u8, Ordering::Relaxed);
}

/// Run `f` with the process trace mode set to `mode`, restoring the
/// previous mode afterwards (even on panic). Mode-sensitive tests are
/// serialized through an internal lock so they cannot observe each
/// other's counters mid-assertion; the lock is not reentrant, so do not
/// nest `with_mode` calls on one thread.
pub fn with_mode<R>(mode: TraceMode, f: impl FnOnce() -> R) -> R {
    static LOCK: Mutex<()> = Mutex::new(());
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore<'a>(u8, #[allow(dead_code)] std::sync::MutexGuard<'a, ()>);
    impl Drop for Restore<'_> {
        fn drop(&mut self) {
            MODE.store(self.0, Ordering::Relaxed);
        }
    }
    let restore = Restore(mode_u8(), guard);
    set_mode(mode);
    let out = f();
    drop(restore);
    out
}

// ---------------------------------------------------------------------------
// Clock.
// ---------------------------------------------------------------------------

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process-wide trace epoch (first use).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

// ---------------------------------------------------------------------------
// Spans.
// ---------------------------------------------------------------------------

/// A typed span field value. `Str` is `&'static str` on purpose: field
/// values are library-controlled identifiers, never user data, so spans
/// allocate nothing beyond their field vector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Value {
    U64(u64),
    I64(i64),
    F64(f64),
    Str(&'static str),
    Bool(bool),
}

/// One completed span, as drained by [`take_events`] / [`flush`].
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// Static span name ("ep.sweep", "factor.wave", …).
    pub name: &'static str,
    /// Obs-assigned thread id (small, stable per thread).
    pub tid: u64,
    /// Process-unique span id (never 0).
    pub id: u64,
    /// Parent span id, 0 for roots.
    pub parent: u64,
    /// Enter time, ns since the trace epoch.
    pub t0_ns: u64,
    /// Exit time, ns since the trace epoch (`t1_ns >= t0_ns`).
    pub t1_ns: u64,
    /// Small typed field map, in insertion order.
    pub fields: Vec<(&'static str, Value)>,
}

static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static DROPPED_EVENTS: AtomicU64 = AtomicU64::new(0);

/// Per-thread completed-event buffer cap: beyond this, new events are
/// counted as dropped instead of buffered, bounding memory when a long
/// run never drains (e.g. the whole test suite under `CSGP_TRACE=full`).
const BUF_CAP: usize = 1 << 16;

type EventBuf = Arc<Mutex<Vec<SpanEvent>>>;

fn registry() -> &'static Mutex<Vec<EventBuf>> {
    static REGISTRY: OnceLock<Mutex<Vec<EventBuf>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

struct ThreadBuf {
    tid: u64,
    /// Innermost open span on this thread (0 = none). Also settable by
    /// [`parent_scope`] to splice cross-thread edges.
    current: Cell<u64>,
    /// Completed events. The mutex is only ever contended by a drain
    /// ([`take_events`]); the owning thread's pushes are effectively
    /// uncontended, which is what keeps Full-mode overhead flat and the
    /// width contract intact (no cross-thread ordering is introduced).
    events: EventBuf,
}

thread_local! {
    static TB: ThreadBuf = {
        let events: EventBuf = Arc::new(Mutex::new(Vec::new()));
        registry().lock().unwrap_or_else(|e| e.into_inner()).push(events.clone());
        ThreadBuf {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            current: Cell::new(0),
            events,
        }
    };
}

struct LiveSpan {
    name: &'static str,
    id: u64,
    parent: u64,
    t0_ns: u64,
    fields: Vec<(&'static str, Value)>,
}

/// RAII span guard. Inert (no id, no timestamps, no allocation) unless
/// [`spans_on`]; records one [`SpanEvent`] into the creating thread's
/// buffer on drop. Create and drop on the same thread.
pub struct Span {
    live: Option<LiveSpan>,
}

#[cold]
fn open_span(name: &'static str) -> LiveSpan {
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    let parent = TB.with(|tb| {
        let p = tb.current.get();
        tb.current.set(id);
        p
    });
    LiveSpan { name, id, parent, t0_ns: now_ns(), fields: Vec::new() }
}

/// Open a span named `name` (a no-op guard unless the mode is Full).
#[inline]
pub fn span(name: &'static str) -> Span {
    if !spans_on() {
        return Span { live: None };
    }
    Span { live: Some(open_span(name)) }
}

impl Span {
    /// Is this guard actually recording? Gate expensive field
    /// computations on this.
    #[inline]
    pub fn is_active(&self) -> bool {
        self.live.is_some()
    }

    /// This span's id (0 when inactive) — feed to [`parent_scope`] on
    /// another thread to parent its spans here.
    #[inline]
    pub fn id(&self) -> u64 {
        self.live.as_ref().map_or(0, |l| l.id)
    }

    /// Attach a typed field (no-op when inactive).
    #[inline]
    pub fn field(&mut self, key: &'static str, value: Value) {
        if let Some(l) = self.live.as_mut() {
            l.fields.push((key, value));
        }
    }

    #[inline]
    pub fn field_u64(&mut self, key: &'static str, v: u64) {
        self.field(key, Value::U64(v));
    }

    #[inline]
    pub fn field_f64(&mut self, key: &'static str, v: f64) {
        self.field(key, Value::F64(v));
    }

    #[inline]
    pub fn field_str(&mut self, key: &'static str, v: &'static str) {
        self.field(key, Value::Str(v));
    }

    #[inline]
    pub fn field_bool(&mut self, key: &'static str, v: bool) {
        self.field(key, Value::Bool(v));
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(live) = self.live.take() {
            let t1_ns = now_ns();
            let LiveSpan { name, id, parent, t0_ns, fields } = live;
            TB.with(|tb| {
                tb.current.set(parent);
                let mut buf = tb.events.lock().unwrap_or_else(|e| e.into_inner());
                if buf.len() < BUF_CAP {
                    buf.push(SpanEvent { name, tid: tb.tid, id, parent, t0_ns, t1_ns, fields });
                } else {
                    DROPPED_EVENTS.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    }
}

/// The innermost open span id on this thread (0 if none or spans off).
#[inline]
pub fn current_span_id() -> u64 {
    if !spans_on() {
        return 0;
    }
    TB.with(|tb| tb.current.get())
}

/// RAII guard installing a foreign span id as this thread's current
/// parent; see [`parent_scope`].
pub struct ParentScope {
    prev: u64,
    active: bool,
}

/// Make spans opened on this thread children of `parent` (a span id from
/// [`Span::id`] / [`current_span_id`] on the issuing thread) until the
/// returned guard drops. No-op when spans are off or `parent` is 0.
pub fn parent_scope(parent: u64) -> ParentScope {
    if !spans_on() || parent == 0 {
        return ParentScope { prev: 0, active: false };
    }
    let prev = TB.with(|tb| {
        let p = tb.current.get();
        tb.current.set(parent);
        p
    });
    ParentScope { prev, active: true }
}

impl Drop for ParentScope {
    fn drop(&mut self) {
        if self.active {
            let prev = self.prev;
            TB.with(|tb| tb.current.set(prev));
        }
    }
}

// ---------------------------------------------------------------------------
// Draining: tests and the JSONL sink.
// ---------------------------------------------------------------------------

/// Drain every thread's completed spans (including long-lived pool
/// workers'), ordered by enter time. Tests call this directly; [`flush`]
/// uses it to feed the sink.
pub fn take_events() -> Vec<SpanEvent> {
    let bufs: Vec<EventBuf> = registry().lock().unwrap_or_else(|e| e.into_inner()).clone();
    let mut out = Vec::new();
    for buf in bufs {
        let mut guard = buf.lock().unwrap_or_else(|e| e.into_inner());
        out.append(&mut guard);
    }
    out.sort_by_key(|e| (e.t0_ns, e.id));
    out
}

/// Events discarded because a thread's buffer hit its cap since the last
/// reset (see `BUF_CAP`).
pub fn dropped_events() -> u64 {
    DROPPED_EVENTS.load(Ordering::Relaxed)
}

static SINK: Mutex<Option<PathBuf>> = Mutex::new(None);

/// Route [`flush`] output to `path` (created/truncated now, appended on
/// each flush).
pub fn set_sink(path: impl AsRef<Path>) -> std::io::Result<()> {
    let p = path.as_ref().to_path_buf();
    std::fs::File::create(&p)?;
    *SINK.lock().unwrap_or_else(|e| e.into_inner()) = Some(p);
    Ok(())
}

/// Drain all buffered spans and append them to the sink as JSONL (one
/// object per line; see ARCHITECTURE.md for the schema). Returns the
/// number of events written; a no-op returning 0 when no sink is set.
pub fn flush() -> std::io::Result<usize> {
    let path = match SINK.lock().unwrap_or_else(|e| e.into_inner()).clone() {
        Some(p) => p,
        None => return Ok(0),
    };
    let events = take_events();
    if events.is_empty() {
        return Ok(0);
    }
    let file = std::fs::OpenOptions::new().append(true).open(&path)?;
    let mut w = std::io::BufWriter::new(file);
    for ev in &events {
        write_event_jsonl(&mut w, ev)?;
    }
    w.flush()?;
    Ok(events.len())
}

fn write_value(w: &mut impl Write, v: &Value) -> std::io::Result<()> {
    match *v {
        Value::U64(x) => write!(w, "{x}"),
        Value::I64(x) => write!(w, "{x}"),
        // Rust's float Display is valid JSON for finite values; map the
        // non-finite ones (first-sweep ΔlogZ is -inf) to null.
        Value::F64(x) if x.is_finite() => write!(w, "{x}"),
        Value::F64(_) => write!(w, "null"),
        // Names and values are library-controlled static ASCII
        // identifiers — nothing to escape.
        Value::Str(s) => write!(w, "\"{s}\""),
        Value::Bool(b) => write!(w, "{b}"),
    }
}

fn write_event_jsonl(w: &mut impl Write, ev: &SpanEvent) -> std::io::Result<()> {
    write!(
        w,
        "{{\"ev\":\"span\",\"name\":\"{}\",\"tid\":{},\"id\":{},\"parent\":",
        ev.name, ev.tid, ev.id
    )?;
    if ev.parent == 0 {
        write!(w, "null")?;
    } else {
        write!(w, "{}", ev.parent)?;
    }
    write!(w, ",\"t0_ns\":{},\"t1_ns\":{},\"fields\":{{", ev.t0_ns, ev.t1_ns)?;
    for (i, (k, v)) in ev.fields.iter().enumerate() {
        if i > 0 {
            write!(w, ",")?;
        }
        write!(w, "\"{k}\":")?;
        write_value(w, v)?;
    }
    writeln!(w, "}}}}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_mode_spans_are_inert() {
        with_mode(TraceMode::Off, || {
            let before = take_events().len();
            {
                let mut s = span("test.inert");
                assert!(!s.is_active());
                assert_eq!(s.id(), 0);
                s.field_u64("k", 1);
            }
            assert_eq!(current_span_id(), 0);
            // nothing new was buffered
            let evs = take_events();
            assert!(evs.iter().all(|e| e.name != "test.inert"), "inert span leaked");
            let _ = before;
        });
    }

    #[test]
    fn full_mode_records_nested_spans_with_parents() {
        with_mode(TraceMode::Full, || {
            let _ = take_events();
            let (outer_id, inner_id);
            {
                let mut outer = span("test.outer");
                assert!(outer.is_active());
                outer_id = outer.id();
                assert_eq!(current_span_id(), outer_id);
                {
                    let inner = span("test.inner");
                    inner_id = inner.id();
                    assert_ne!(inner_id, outer_id);
                    assert_eq!(current_span_id(), inner_id);
                }
                assert_eq!(current_span_id(), outer_id);
                outer.field_f64("x", 2.5);
            }
            let evs = take_events();
            let outer = evs.iter().find(|e| e.id == outer_id).expect("outer recorded");
            let inner = evs.iter().find(|e| e.id == inner_id).expect("inner recorded");
            assert_eq!(inner.parent, outer_id);
            assert_eq!(outer.name, "test.outer");
            assert!(outer.t0_ns <= inner.t0_ns && inner.t1_ns <= outer.t1_ns);
            assert_eq!(outer.fields, vec![("x", Value::F64(2.5))]);
        });
    }

    #[test]
    fn parent_scope_splices_and_restores() {
        with_mode(TraceMode::Full, || {
            let _ = take_events();
            let child_id;
            {
                let _scope = parent_scope(4242);
                assert_eq!(current_span_id(), 4242);
                let c = span("test.spliced");
                child_id = c.id();
            }
            assert_eq!(current_span_id(), 0);
            let evs = take_events();
            let c = evs.iter().find(|e| e.id == child_id).expect("spliced recorded");
            assert_eq!(c.parent, 4242);
        });
    }

    #[test]
    fn jsonl_lines_are_well_formed() {
        let ev = SpanEvent {
            name: "ep.sweep",
            tid: 3,
            id: 17,
            parent: 0,
            t0_ns: 5,
            t1_ns: 9,
            fields: vec![
                ("sweep", Value::U64(2)),
                ("dlogz", Value::F64(f64::NEG_INFINITY)),
                ("backend", Value::Str("sparse")),
                ("damped", Value::Bool(true)),
                ("delta", Value::F64(0.25)),
            ],
        };
        let mut out = Vec::new();
        write_event_jsonl(&mut out, &ev).unwrap();
        let line = String::from_utf8(out).unwrap();
        assert_eq!(
            line,
            "{\"ev\":\"span\",\"name\":\"ep.sweep\",\"tid\":3,\"id\":17,\"parent\":null,\
             \"t0_ns\":5,\"t1_ns\":9,\"fields\":{\"sweep\":2,\"dlogz\":null,\
             \"backend\":\"sparse\",\"damped\":true,\"delta\":0.25}}\n"
        );
    }

    #[test]
    fn with_mode_restores_previous_mode() {
        let before = mode();
        with_mode(TraceMode::Counters, || {
            assert!(counters_on());
            assert!(!spans_on());
        });
        assert_eq!(mode(), before);
    }
}
