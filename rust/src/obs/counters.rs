//! Process-wide atomic counters, max-gauges and the named latency
//! histograms, plus [`snapshot`] / [`summary`] for benches and the CLI.
//!
//! Everything here is a `static` with const initialization — no
//! registration, no locks, no allocation. Increments are gated on
//! [`counters_on`](super::counters_on), so with `CSGP_TRACE` unset every
//! site is one relaxed load and a skipped branch.

use std::sync::atomic::{AtomicU64, Ordering};

use super::hist::Histogram;
use crate::bench::fmt_duration;
use std::time::Duration;

/// A monotone event counter (relaxed atomic, gated on the trace mode).
pub struct Counter(AtomicU64);

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add `n` (no-op unless counters are on).
    #[inline]
    pub fn add(&self, n: u64) {
        if super::counters_on() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// A high-watermark gauge: `record` keeps the maximum value seen.
pub struct MaxGauge(AtomicU64);

impl Default for MaxGauge {
    fn default() -> Self {
        Self::new()
    }
}

impl MaxGauge {
    pub const fn new() -> MaxGauge {
        MaxGauge(AtomicU64::new(0))
    }

    /// Raise the watermark to `v` if higher (no-op unless counters on).
    #[inline]
    pub fn record(&self, v: u64) {
        if super::counters_on() {
            self.0.fetch_max(v, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

// --- PatternCache -----------------------------------------------------------

/// Pattern/ordering/symbolic reuse across hyperparameter steps.
pub static CACHE_HIT: Counter = Counter::new();
pub static CACHE_MISS: Counter = Counter::new();
/// Hits where the support ellipsoid shrank: the superset pattern was
/// reused with re-evaluated values.
pub static CACHE_SHRINK_REUSE: Counter = Counter::new();
/// Misses where a previously built pattern existed but the support grew,
/// forcing new neighbor queries + ordering + symbolic analysis.
pub static CACHE_GROW_REANALYZE: Counter = Counter::new();

// --- par:: pool -------------------------------------------------------------

/// Chunks executed by any participant of a fanned-out region.
pub static POOL_CHUNKS: Counter = Counter::new();
/// Chunks executed by a pool worker rather than the issuing thread.
pub static POOL_STEALS: Counter = Counter::new();
/// Total in-chunk busy time across all participants.
pub static POOL_BUSY_NS: Counter = Counter::new();
/// Time the issuing thread spent waiting on stragglers after running out
/// of chunks — the pool's idle-time / imbalance tail.
pub static POOL_CALLER_WAIT_NS: Counter = Counter::new();
/// Worst per-region imbalance seen: max participant busy time over the
/// mean, in permille (1000 = perfectly balanced).
pub static POOL_IMBALANCE_MAX_PERMILLE: MaxGauge = MaxGauge::new();

// --- EP ---------------------------------------------------------------------

pub static EP_SWEEPS: Counter = Counter::new();
pub static EP_SITE_VISITS: Counter = Counter::new();
/// Site-update merges performed with damping < 1.
pub static EP_DAMPED_UPDATES: Counter = Counter::new();
/// Site updates skipped because the proposed (tau, nu) was non-finite or
/// the new site precision was negative — the per-site recovery guard.
pub static EP_SKIPPED_SITES: Counter = Counter::new();
/// Sweep-level recoveries: sites restored to the last-good snapshot and
/// damping halved after a divergence signal.
pub static EP_ROLLBACKS: Counter = Counter::new();

// --- solver stack -----------------------------------------------------------

pub static FACTOR_REFACTORS: Counter = Counter::new();
pub static FACTOR_WAVES: Counter = Counter::new();
/// Factorization attempts retried with escalated diagonal jitter after a
/// non-positive pivot (pivot recovery; zero on healthy inputs).
pub static FACTOR_JITTER_RETRIES: Counter = Counter::new();
/// Sparse / dense triangular solve calls (per-site RHS solves dominate).
pub static SOLVES: Counter = Counter::new();
pub static TAKAHASHI_RUNS: Counter = Counter::new();

// --- coordinator ------------------------------------------------------------

pub static JOBS_DONE: Counter = Counter::new();
pub static JOBS_FAILED: Counter = Counter::new();
/// Degradation-ladder rungs taken: a failed fit retried with jitter
/// headroom, a damped sequential sweep, or the dense fallback.
pub static JOB_RETRIES: Counter = Counter::new();

// --- online serving ---------------------------------------------------------

/// Online `GpClassifier::update` calls that resumed from the old fixed
/// point (factor embed + partial sweep, or a warm-started run).
pub static ONLINE_UPDATES: Counter = Counter::new();
/// Online updates that fell back to a cold refit on the union (backend
/// without an incremental path, oversized batch, or a failed resume).
pub static ONLINE_REFITS: Counter = Counter::new();
/// Model snapshots written (after the atomic rename).
pub static SNAPSHOT_SAVES: Counter = Counter::new();
/// Model snapshots successfully loaded into a predict-ready model.
pub static SNAPSHOT_LOADS: Counter = Counter::new();
/// Prediction requests rejected by admission control (queue full).
pub static SVC_REJECTED: Counter = Counter::new();

// --- fault injection --------------------------------------------------------

/// Faults actually fired by an installed [`crate::fault::Plan`] (zero
/// unless a plan is active; clean runs assert it stays zero).
pub static FAULTS_INJECTED: Counter = Counter::new();

// --- latency histograms -----------------------------------------------------

/// Per-chunk latency across every fanned-out pool region.
pub static POOL_CHUNK_NS: Histogram = Histogram::new();
/// Coordinator fit-job latency (spec build + EP, optionally SCG).
pub static JOB_FIT_NS: Histogram = Histogram::new();
/// Coordinator inference-job latency (EP at fixed hyperparameters).
pub static JOB_INFER_NS: Histogram = Histogram::new();
/// Prediction-service batch compute latency.
pub static SVC_BATCH_NS: Histogram = Histogram::new();
/// Prediction-service per-request service time (queueing included).
pub static SVC_REQUEST_NS: Histogram = Histogram::new();

/// Defines [`Snapshot`] plus everything that must stay in lock-step with
/// its field list: [`snapshot`] (the reads), [`Snapshot::delta`]
/// (field-wise difference) and [`Snapshot::fields`] (the named view the
/// metrics exporter and `trace analyze` serialize). One macro invocation
/// so a new counter cannot be added to one and forgotten in another.
macro_rules! snapshot_def {
    ($($(#[$doc:meta])* $name:ident = $read:expr;)*) => {
        /// A point-in-time copy of every counter (not the histograms).
        /// Benches snapshot before/after a measured region and report the
        /// difference; the metrics exporter snapshots per interval and
        /// reports both absolutes and [`Snapshot::delta`]s.
        #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
        pub struct Snapshot {
            $($(#[$doc])* pub $name: u64,)*
        }

        /// Read every counter at once (including the span-buffer drop
        /// count, [`super::dropped_events`]).
        pub fn snapshot() -> Snapshot {
            Snapshot { $($name: $read,)* }
        }

        impl Snapshot {
            /// Field-wise `self - earlier`, saturating at zero — the
            /// interval view the metrics exporter and per-request
            /// attribution need (counters are monotone, so deltas are the
            /// meaningful quantity between two points in time).
            pub fn delta(&self, earlier: &Snapshot) -> Snapshot {
                Snapshot { $($name: self.$name.saturating_sub(earlier.$name),)* }
            }

            /// Every field as a `(name, value)` pair, in declaration
            /// order — the serialization view (exporter JSONL, profile
            /// reports) that cannot drift from the struct.
            pub fn fields(&self) -> Vec<(&'static str, u64)> {
                vec![$((stringify!($name), self.$name),)*]
            }
        }
    };
}

snapshot_def! {
    cache_hit = CACHE_HIT.get();
    cache_miss = CACHE_MISS.get();
    cache_shrink_reuse = CACHE_SHRINK_REUSE.get();
    cache_grow_reanalyze = CACHE_GROW_REANALYZE.get();
    pool_chunks = POOL_CHUNKS.get();
    pool_steals = POOL_STEALS.get();
    pool_busy_ns = POOL_BUSY_NS.get();
    pool_caller_wait_ns = POOL_CALLER_WAIT_NS.get();
    ep_sweeps = EP_SWEEPS.get();
    ep_site_visits = EP_SITE_VISITS.get();
    ep_damped_updates = EP_DAMPED_UPDATES.get();
    ep_skipped_sites = EP_SKIPPED_SITES.get();
    ep_rollbacks = EP_ROLLBACKS.get();
    factor_refactors = FACTOR_REFACTORS.get();
    factor_waves = FACTOR_WAVES.get();
    factor_jitter_retries = FACTOR_JITTER_RETRIES.get();
    solves = SOLVES.get();
    takahashi_runs = TAKAHASHI_RUNS.get();
    jobs_done = JOBS_DONE.get();
    jobs_failed = JOBS_FAILED.get();
    job_retries = JOB_RETRIES.get();
    online_updates = ONLINE_UPDATES.get();
    online_refits = ONLINE_REFITS.get();
    snapshot_saves = SNAPSHOT_SAVES.get();
    snapshot_loads = SNAPSHOT_LOADS.get();
    svc_rejected = SVC_REJECTED.get();
    faults_injected = FAULTS_INJECTED.get();
    /// Span events discarded because a thread's buffer hit its cap —
    /// nonzero means a trace (and any profile built from it) is partial.
    span_dropped = super::dropped_events();
}

/// Zero every counter, gauge and histogram. Benches call this between
/// measurement windows; not atomic with respect to concurrent recording.
pub fn reset_all() {
    for c in [
        &CACHE_HIT,
        &CACHE_MISS,
        &CACHE_SHRINK_REUSE,
        &CACHE_GROW_REANALYZE,
        &POOL_CHUNKS,
        &POOL_STEALS,
        &POOL_BUSY_NS,
        &POOL_CALLER_WAIT_NS,
        &EP_SWEEPS,
        &EP_SITE_VISITS,
        &EP_DAMPED_UPDATES,
        &EP_SKIPPED_SITES,
        &EP_ROLLBACKS,
        &FACTOR_REFACTORS,
        &FACTOR_WAVES,
        &FACTOR_JITTER_RETRIES,
        &SOLVES,
        &TAKAHASHI_RUNS,
        &JOBS_DONE,
        &JOBS_FAILED,
        &JOB_RETRIES,
        &ONLINE_UPDATES,
        &ONLINE_REFITS,
        &SNAPSHOT_SAVES,
        &SNAPSHOT_LOADS,
        &SVC_REJECTED,
        &FAULTS_INJECTED,
    ] {
        c.reset();
    }
    POOL_IMBALANCE_MAX_PERMILLE.reset();
    super::DROPPED_EVENTS.store(0, Ordering::Relaxed);
    for h in [&POOL_CHUNK_NS, &JOB_FIT_NS, &JOB_INFER_NS, &SVC_BATCH_NS, &SVC_REQUEST_NS] {
        h.reset();
    }
}

/// Human-readable report of every live counter, gauge and histogram —
/// the coordinator CLI and the benches embed this after a run. Latency
/// histograms report count / p50 / p90 / p99, matching the percentile
/// fields [`crate::bench::Stats`] reports for exact samples.
pub fn summary() -> String {
    use std::fmt::Write;
    let s = snapshot();
    let ns = |v: u64| fmt_duration(Duration::from_nanos(v));
    let mut out = String::new();
    let _ = writeln!(
        out,
        "obs summary (mode={:?}, spans_dropped={}):",
        super::mode(),
        s.span_dropped
    );
    let _ = writeln!(
        out,
        "  ep: sweeps={} site_visits={} damped_updates={} skipped_sites={} rollbacks={}",
        s.ep_sweeps, s.ep_site_visits, s.ep_damped_updates, s.ep_skipped_sites, s.ep_rollbacks
    );
    let _ = writeln!(
        out,
        "  solver: refactors={} waves={} jitter_retries={} solves={} takahashi={}",
        s.factor_refactors, s.factor_waves, s.factor_jitter_retries, s.solves, s.takahashi_runs
    );
    let _ = writeln!(
        out,
        "  cache: hit={} miss={} shrink_reuse={} grow_reanalyze={}",
        s.cache_hit, s.cache_miss, s.cache_shrink_reuse, s.cache_grow_reanalyze
    );
    let _ = writeln!(
        out,
        "  pool: chunks={} steals={} busy={} caller_wait={} imbalance_max={}permille",
        s.pool_chunks,
        s.pool_steals,
        ns(s.pool_busy_ns),
        ns(s.pool_caller_wait_ns),
        POOL_IMBALANCE_MAX_PERMILLE.get()
    );
    let _ = writeln!(
        out,
        "  jobs: done={} failed={} retries={}",
        s.jobs_done, s.jobs_failed, s.job_retries
    );
    let _ = writeln!(
        out,
        "  serving: online_updates={} online_refits={} snapshot_saves={} \
         snapshot_loads={} rejected={}",
        s.online_updates, s.online_refits, s.snapshot_saves, s.snapshot_loads, s.svc_rejected
    );
    if s.faults_injected > 0 {
        let _ = writeln!(out, "  fault: injected={}", s.faults_injected);
    }
    for (name, h) in [
        ("pool.chunk", &POOL_CHUNK_NS),
        ("job.fit", &JOB_FIT_NS),
        ("job.infer", &JOB_INFER_NS),
        ("svc.batch", &SVC_BATCH_NS),
        ("svc.request", &SVC_REQUEST_NS),
    ] {
        if h.count() == 0 {
            continue;
        }
        let _ = writeln!(
            out,
            "  hist {name}: count={} p50={} p90={} p99={}",
            h.count(),
            fmt_duration(h.percentile(50.0)),
            fmt_duration(h.percentile(90.0)),
            fmt_duration(h.percentile(99.0))
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::{with_mode, TraceMode};
    use super::*;

    #[test]
    fn counters_are_gated_on_mode() {
        static LOCAL: Counter = Counter::new();
        with_mode(TraceMode::Off, || {
            LOCAL.add(5);
            assert_eq!(LOCAL.get(), 0);
        });
        with_mode(TraceMode::Counters, || {
            LOCAL.add(5);
            LOCAL.add(2);
            assert_eq!(LOCAL.get(), 7);
        });
        LOCAL.reset();
        assert_eq!(LOCAL.get(), 0);
    }

    #[test]
    fn gauge_keeps_the_maximum() {
        static G: MaxGauge = MaxGauge::new();
        with_mode(TraceMode::Counters, || {
            G.record(3);
            G.record(9);
            G.record(4);
            assert_eq!(G.get(), 9);
        });
        G.reset();
        assert_eq!(G.get(), 0);
    }

    #[test]
    fn summary_mentions_every_section() {
        let text = summary();
        for needle in
            ["obs summary", "spans_dropped=", "ep:", "solver:", "cache:", "pool:", "jobs:"]
        {
            assert!(text.contains(needle), "missing {needle:?} in {text}");
        }
    }

    #[test]
    fn snapshot_delta_is_fieldwise_and_saturating() {
        let a = Snapshot { ep_sweeps: 10, solves: 100, ..Snapshot::default() };
        // solves went backwards (a reset between snapshots) — must not underflow
        let b = Snapshot { ep_sweeps: 4, solves: 120, ..Snapshot::default() };
        let d = a.delta(&b);
        assert_eq!(d.ep_sweeps, 6);
        assert_eq!(d.solves, 0);
        assert_eq!(d.cache_hit, 0);
    }

    /// `fields()` is the exporter's serialization view: one entry per
    /// struct field, names matching the field identifiers, values
    /// matching the struct.
    #[test]
    fn snapshot_fields_cover_every_counter() {
        let s = Snapshot { ep_sweeps: 3, span_dropped: 7, ..Snapshot::default() };
        let fields = s.fields();
        assert_eq!(
            fields.len(),
            std::mem::size_of::<Snapshot>() / std::mem::size_of::<u64>(),
            "fields() must cover every Snapshot field"
        );
        let get = |name: &str| fields.iter().find(|(k, _)| *k == name).map(|(_, v)| *v);
        assert_eq!(get("ep_sweeps"), Some(3));
        assert_eq!(get("span_dropped"), Some(7));
        assert_eq!(get("svc_rejected"), Some(0));
    }
}
