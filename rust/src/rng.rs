//! Self-contained PRNG and sampling utilities.
//!
//! The build environment vendors no `rand` crate, so we carry a small
//! xoshiro256** implementation (Blackman & Vigna) plus the handful of
//! distributions the experiments need. Deterministic given a seed, which
//! every benchmark and test relies on for reproducibility.

/// xoshiro256** — a fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small / similar seeds still produce
    /// well-separated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p = self.permutation(n);
        p.truncate(k);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..20000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 20000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let xs = r.normal_vec(50000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
