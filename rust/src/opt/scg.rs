//! Scaled conjugate gradients (Møller 1993) — the optimizer the paper uses
//! ("optimization was conducted using the scaled conjugate gradient
//! method", via GPstuff/netlab). Minimizes `f` given `(f, ∇f)`; no line
//! searches, one extra gradient evaluation per step for the Hessian-vector
//! finite difference.

/// Result of an SCG run.
#[derive(Clone, Debug)]
pub struct ScgResult {
    pub x: Vec<f64>,
    pub f: f64,
    pub iterations: usize,
    pub fn_evals: usize,
    pub grad_evals: usize,
    pub converged: bool,
}

/// Options.
#[derive(Clone, Copy, Debug)]
pub struct ScgOptions {
    pub max_iters: usize,
    /// Stop when both |Δx| and |Δf| fall below these.
    pub x_tol: f64,
    pub f_tol: f64,
}

impl Default for ScgOptions {
    fn default() -> Self {
        ScgOptions { max_iters: 100, x_tol: 1e-5, f_tol: 1e-6 }
    }
}

fn norm2(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum()
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Minimize `f` from `x0`. `eval` returns `(f(x), ∇f(x))`.
pub fn scg(
    x0: &[f64],
    mut eval: impl FnMut(&[f64]) -> (f64, Vec<f64>),
    opts: &ScgOptions,
) -> ScgResult {
    let n = x0.len();
    let sigma0 = 1e-4;
    let mut lambda = 1e-6f64;
    let mut lambda_bar = 0.0f64;
    let mut x = x0.to_vec();
    let (mut fnow, mut grad) = eval(&x);
    let mut fn_evals = 1;
    let mut grad_evals = 1;
    let mut d: Vec<f64> = grad.iter().map(|g| -g).collect();
    let mut success = true;
    let mut n_successes = 0usize;
    let mut converged = false;
    let mut iterations = 0;
    #[allow(unused_assignments)]
    let mut delta = 0.0f64;
    let mut theta = 0.0f64; // d' H d approximation

    for k in 0..opts.max_iters {
        iterations = k + 1;
        let d2 = norm2(&d);
        if d2 < 1e-300 {
            converged = true;
            break;
        }
        if success {
            // Hessian-vector product via finite differences along d
            let sigma = sigma0 / d2.sqrt();
            let xs: Vec<f64> = x.iter().zip(&d).map(|(xi, di)| xi + sigma * di).collect();
            let (_, gs) = eval(&xs);
            fn_evals += 1;
            grad_evals += 1;
            theta = (0..n).map(|i| (gs[i] - grad[i]) * d[i]).sum::<f64>() / sigma;
        }
        // scale to make delta positive definite
        delta = theta + (lambda - lambda_bar) * d2;
        if delta <= 0.0 {
            lambda_bar = 2.0 * (lambda - delta / d2);
            delta = -theta + lambda * d2;
            lambda = lambda_bar;
        }
        let mu = -dot(&d, &grad); // note: mu = d'r with r = -grad
        let alpha = mu / delta;
        let xnew: Vec<f64> = x.iter().zip(&d).map(|(xi, di)| xi + alpha * di).collect();
        let (fnew, gnew) = eval(&xnew);
        fn_evals += 1;
        grad_evals += 1;
        let big_delta = 2.0 * delta * (fnow - fnew) / (mu * mu);

        if big_delta >= 0.0 {
            // successful step
            let dx2: f64 = alpha * alpha * d2;
            let df = (fnow - fnew).abs();
            x = xnew;
            let grad_old = std::mem::replace(&mut grad, gnew);
            fnow = fnew;
            lambda_bar = 0.0;
            success = true;
            n_successes += 1;
            if big_delta >= 0.75 {
                lambda *= 0.25;
            }
            // Polak-Ribière-style restart every n successes
            if n_successes % n == 0 {
                d = grad.iter().map(|g| -g).collect();
            } else {
                let beta = (norm2(&grad) - dot(&grad, &grad_old)) / mu;
                for i in 0..n {
                    d[i] = -grad[i] + beta * d[i];
                }
            }
            if dx2.sqrt() < opts.x_tol && df < opts.f_tol {
                converged = true;
                break;
            }
        } else {
            lambda_bar = lambda;
            success = false;
        }
        if big_delta < 0.25 {
            lambda += delta * (1.0 - big_delta) / d2;
        }
        if lambda > 1e100 {
            break; // cannot make progress
        }
    }

    ScgResult { x, f: fnow, iterations, fn_evals, grad_evals, converged }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f = ½ (x-a)' D (x-a)
        let a = [1.0, -2.0, 3.0];
        let d = [1.0, 4.0, 0.5];
        let res = scg(
            &[0.0, 0.0, 0.0],
            |x| {
                let f: f64 =
                    (0..3).map(|i| 0.5 * d[i] * (x[i] - a[i]) * (x[i] - a[i])).sum();
                let g: Vec<f64> = (0..3).map(|i| d[i] * (x[i] - a[i])).collect();
                (f, g)
            },
            &ScgOptions::default(),
        );
        assert!(res.converged, "not converged: {res:?}");
        for i in 0..3 {
            assert!((res.x[i] - a[i]).abs() < 1e-4, "x[{i}] = {}", res.x[i]);
        }
    }

    #[test]
    fn minimizes_rosenbrock() {
        let res = scg(
            &[-1.2, 1.0],
            |x| {
                let (a, b) = (x[0], x[1]);
                let f = (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2);
                let g = vec![
                    -2.0 * (1.0 - a) - 400.0 * a * (b - a * a),
                    200.0 * (b - a * a),
                ];
                (f, g)
            },
            &ScgOptions { max_iters: 3000, x_tol: 1e-10, f_tol: 1e-12 },
        );
        assert!(res.f < 1e-5, "f = {} at {:?}", res.f, res.x);
        assert!((res.x[0] - 1.0).abs() < 1e-2);
    }

    #[test]
    fn handles_already_optimal_start() {
        let res = scg(
            &[0.0],
            |x| (x[0] * x[0], vec![2.0 * x[0]]),
            &ScgOptions::default(),
        );
        assert!(res.f < 1e-12);
    }

    #[test]
    fn respects_iteration_cap() {
        let res = scg(
            &[5.0],
            |x| (x[0] * x[0], vec![2.0 * x[0]]),
            &ScgOptions { max_iters: 2, x_tol: 0.0, f_tol: 0.0 },
        );
        assert!(res.iterations <= 2);
    }
}
