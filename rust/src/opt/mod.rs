//! Optimizers for hyperparameter MAP search.
pub mod scg;
