//! `csgp` — Sparse expectation propagation for binary Gaussian process
//! classification with compactly supported covariance functions.
//!
//! Reproduction of Vanhatalo & Vehtari, *Speeding up the binary Gaussian
//! process classification* (stat.ML, 2012). The crate is organised as the
//! L3 (rust coordinator) layer of a three-layer rust + JAX + Pallas stack:
//!
//! * [`sparse`] — from-scratch sparse linear algebra: CSC matrices,
//!   elimination trees, symbolic analysis, up-looking LDLᵀ factorization,
//!   sparse triangular solves, rank-one update/downdate, the Davis–Hager
//!   row-modification (`ldlrowmodify`, the paper's Algorithm 2) and the
//!   Takahashi sparsified inverse.
//! * [`gp`] — covariance functions (squared exponential, the Wendland
//!   piecewise polynomials `pp0..pp3`, Matérn), the probit likelihood,
//!   dense EP (Rasmussen & Williams Alg. 3.5), the paper's sparse EP
//!   (Algorithm 1), FIC + EP, marginal likelihood and gradients,
//!   hyperpriors and prediction.
//! * [`opt`] — scaled conjugate gradients for hyperparameter MAP search.
//! * [`data`] — the paper's synthetic cluster workload (§6.1), UCI-like
//!   dataset generators and the cross-validation harness.
//! * [`runtime`] — PJRT (XLA) client wrapper that loads AOT-compiled
//!   covariance / probit artifacts produced by `python/compile/aot.py`.
//! * [`coordinator`] — training-job manager and a batching prediction
//!   service (threads + channels).
//! * [`bench`] — a minimal measurement harness used by `benches/`.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod gp;
pub mod metrics;
pub mod opt;
pub mod rng;
pub mod runtime;
pub mod sparse;

#[cfg(test)]
pub(crate) mod testutil;
