//! `csgp` — Sparse expectation propagation for binary Gaussian process
//! classification with compactly supported covariance functions.
//!
//! Reproduction of Vanhatalo & Vehtari, *Speeding up the binary Gaussian
//! process classification* (stat.ML, 2012). The crate is organised as the
//! L3 (rust coordinator) layer of a three-layer rust + JAX + Pallas stack:
//!
//! * [`sparse`] — from-scratch sparse linear algebra: CSC matrices,
//!   elimination trees, the fill-reducing ordering subsystem (RCM,
//!   quotient-graph min-degree, nested dissection with separator trees,
//!   and the pattern-statistics `Auto` policy the factorization-bound
//!   backends default to), symbolic analysis with supernode detection, a
//!   supernodal wave-parallel LDLᵀ factorization (with the serial
//!   up-looking kernel kept as its oracle), sparse triangular solves,
//!   rank-one update/downdate, the Davis–Hager row-modification
//!   (`ldlrowmodify`, the paper's Algorithm 2), the Takahashi sparsified
//!   inverse, and a sparse-plus-low-rank Woodbury solver (`lowrank`) for
//!   `S + U Uᵀ` systems. See `docs/ARCHITECTURE.md` for the full tour.
//! * [`geom`] — spatial neighbor indices (grid cell list for low
//!   dimension, kd-tree above it) answering the radius-`max(lengthscales)`
//!   queries that make compact-support covariance assembly `O(n·k)`
//!   instead of the all-pairs `O(n²)` scan.
//! * [`gp`] — covariance functions (squared exponential, the Wendland
//!   piecewise polynomials `pp0..pp3`, Matérn), the probit likelihood,
//!   dense EP (Rasmussen & Williams Alg. 3.5), the paper's sparse EP
//!   (Algorithm 1), FIC + EP, the CS+FIC hybrid (`csfic`: sparse local
//!   term plus low-rank global term, never densified), marginal
//!   likelihood and gradients, hyperpriors and prediction.
//! * [`opt`] — scaled conjugate gradients for hyperparameter MAP search.
//! * [`data`] — the paper's synthetic cluster workload (§6.1), UCI-like
//!   dataset generators and the cross-validation harness.
//! * [`runtime`] — artifact runtime for the covariance / probit kernels
//!   compiled by `python/compile/aot.py`; a native interpreter by default,
//!   with the PJRT (XLA) path behind the off-by-default `xla` feature.
//! * [`coordinator`] — training-job manager and a batching prediction
//!   service (threads + channels).
//! * [`par`] — scoped, chunk-stealing worker pool (std threads +
//!   channels) behind every data-parallel hot loop: per-site variance
//!   solves, Takahashi gradient waves, covariance assembly, batched
//!   prediction. Sized by `CSGP_THREADS` / available parallelism;
//!   results are bitwise-identical to the serial path at any width.
//! * [`obs`] — structured tracing + metrics: spans over EP sweeps /
//!   factorization waves / pool chunks / coordinator jobs drained to a
//!   JSONL sink, plus process-wide counters, gauges and latency
//!   histograms. Gated by `CSGP_TRACE` (off / counters / full) and
//!   provably inert with respect to results when off.
//! * [`fault`] — deterministic fault injection (`CSGP_FAULT` / a
//!   programmatic [`fault::Plan`]): one-shot pivot failures, NaN site
//!   updates and slow pool chunks at chosen points, so every recovery
//!   path (jittered refactorization, EP rollback, the coordinator's
//!   degradation ladder) is exercised by tests rather than hoped-for.
//! * [`bench`] — a minimal measurement harness used by `benches/`.
//!
//! # Structure reuse contract
//!
//! Covariance *structure* (sparsity pattern, fill-reducing ordering,
//! symbolic Cholesky analysis) is decoupled from covariance *values*.
//! [`gp::cache::PatternCache`] owns the structure for one training set:
//! hyperparameter moves that keep the ARD support ellipsoid inside the
//! cached one — σ²-only steps, per-axis-shrinking length-scales — reuse
//! the cached (superset) pattern, on which re-evaluated values reproduce
//! the exact assembly (out-of-support entries are exact zeros). Only
//! support growth along some axis triggers new neighbor queries, a new
//! ordering and a new symbolic analysis. `SparseEp::log_z_grad` evaluates gradients on the
//! pattern its run factored, so run/gradient pattern agreement is
//! structural rather than asserted.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod geom;
pub mod gp;
pub mod metrics;
pub mod obs;
pub mod opt;
pub mod par;
pub mod rng;
pub mod runtime;
pub mod sparse;

#[cfg(test)]
pub(crate) mod testutil;
