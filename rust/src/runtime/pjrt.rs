//! PJRT (XLA) execution path — compiled only with `--features xla`.
//!
//! The artifacts under `artifacts/` are HLO text compiled ahead of time by
//! `python/compile/aot.py`; executing them requires PJRT bindings that are
//! not vendored into this offline build. Until they are, this module only
//! reports whether the bindings are present, and [`super::Runtime`] falls
//! back to the native interpreter — enabling the feature is therefore
//! always safe. The binding surface the loader expects is documented in
//! the git history of `runtime/client.rs` (PJRT CPU client, compile-once
//! executable cache keyed by artifact name).

use std::path::Path;

/// Are executable PJRT bindings available for this artifact directory?
/// Always `false` until the bindings are vendored.
pub fn bindings_available(_dir: &Path) -> bool {
    false
}
