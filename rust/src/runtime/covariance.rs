//! Tiled covariance assembly through the AOT artifact.
//!
//! The L3 coordinator asks for a full (symmetric) covariance matrix; this
//! assembler cuts the point set into 128-row blocks, runs the
//! `cov_tile_<kind>` executable per block pair (upper triangle only,
//! mirrored), and sparsifies the result into a CSC matrix — compact
//! supports yield exact zeros at r ≥ 1, so the sparsification is
//! pattern-exact, not a numerical threshold.

use anyhow::{anyhow, Result};

use crate::gp::covariance::{CovFunction, CovKind};
use crate::runtime::client::{Runtime, DMAX, TILE};
use crate::sparse::csc::CscMatrix;

/// Covariance assembly backend running on the PJRT executables.
pub struct XlaCovarianceAssembler<'rt> {
    rt: &'rt Runtime,
}

impl<'rt> XlaCovarianceAssembler<'rt> {
    pub fn new(rt: &'rt Runtime) -> Self {
        XlaCovarianceAssembler { rt }
    }

    fn artifact_name(kind: CovKind) -> String {
        format!("cov_tile_{}", kind.name())
    }

    /// Pack a block of points into a zero-padded (TILE, DMAX) buffer.
    fn pack_block(x: &[Vec<f64>], lo: usize, hi: usize, d: usize) -> Vec<f64> {
        let mut buf = vec![0.0; TILE * DMAX];
        for (bi, xi) in x[lo..hi].iter().enumerate() {
            buf[bi * DMAX..bi * DMAX + d].copy_from_slice(xi);
        }
        buf
    }

    /// Dense covariance values between two blocks via the artifact.
    fn tile(
        &self,
        cov: &CovFunction,
        x: &[Vec<f64>],
        lo1: usize,
        hi1: usize,
        lo2: usize,
        hi2: usize,
    ) -> Result<Vec<f64>> {
        let d = cov.lengthscales.len();
        if d > DMAX {
            return Err(anyhow!("input dim {d} exceeds artifact DMAX {DMAX}"));
        }
        let b1 = Self::pack_block(x, lo1, hi1, d);
        let b2 = Self::pack_block(x, lo2, hi2, d);
        let mut inv_ls2 = vec![0.0; DMAX];
        for (dst, l) in inv_ls2.iter_mut().zip(&cov.lengthscales) {
            *dst = 1.0 / (l * l);
        }
        let jexp = match cov.kind {
            CovKind::Pp(_) => cov.wendland_j(),
            _ => 0.0,
        };
        let scal = vec![cov.sigma2, jexp];
        let tdims = [TILE as i64, DMAX as i64];
        let out = self.rt.run_f64(
            &Self::artifact_name(cov.kind),
            &[
                (&b1, &tdims),
                (&b2, &tdims),
                (&inv_ls2, &[DMAX as i64]),
                (&scal, &[2i64]),
            ],
        )?;
        Ok(out.into_iter().next().ok_or_else(|| anyhow!("no output"))?)
    }

    /// Assemble the full symmetric covariance matrix of `x`, sparsified.
    /// Matches `CovFunction::cov_matrix` to f64 round-off.
    pub fn cov_matrix(&self, cov: &CovFunction, x: &[Vec<f64>]) -> Result<CscMatrix> {
        let n = x.len();
        let nblocks = n.div_ceil(TILE);
        // tile results stored per (block row, block col), upper triangle
        let mut triplets: Vec<(usize, usize, f64)> = Vec::new();
        let compact = cov.is_compact();
        for br in 0..nblocks {
            let (lo1, hi1) = (br * TILE, ((br + 1) * TILE).min(n));
            for bc in br..nblocks {
                let (lo2, hi2) = (bc * TILE, ((bc + 1) * TILE).min(n));
                let vals = self.tile(cov, x, lo1, hi1, lo2, hi2)?;
                for i in 0..(hi1 - lo1) {
                    for j in 0..(hi2 - lo2) {
                        let (gi, gj) = (lo1 + i, lo2 + j);
                        if gj < gi {
                            continue; // handled by the mirrored entry
                        }
                        if gi == gj {
                            // The ‖a‖²+‖b‖²−2abᵀ distance loses ~√ε near
                            // r = 0; the diagonal is k(x,x) = σ² exactly.
                            triplets.push((gi, gj, cov.sigma2));
                            continue;
                        }
                        let v = vals[i * TILE + j];
                        if !compact || v != 0.0 {
                            triplets.push((gi, gj, v));
                            triplets.push((gj, gi, v));
                        }
                    }
                }
            }
        }
        Ok(CscMatrix::from_triplets(n, n, &triplets))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_points;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    /// The cross-layer agreement test: XLA-assembled covariance equals the
    /// native rust covariance entry for entry, pattern included.
    #[test]
    fn xla_assembly_matches_native() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let rt = Runtime::open_default().unwrap();
        let asm = XlaCovarianceAssembler::new(&rt);
        // n > TILE to exercise multi-block assembly
        let x = random_points(150, 3, 8.0, 99);
        for kind in [CovKind::Se, CovKind::Pp(0), CovKind::Pp(3), CovKind::Matern52] {
            let mut cov = CovFunction::new(kind, 3, 1.4, 2.0);
            cov.lengthscales = vec![2.0, 1.0, 3.0];
            let got = asm.cov_matrix(&cov, &x).unwrap();
            let want = cov.cov_matrix(&x);
            assert_eq!(got.col_ptr, want.col_ptr, "{kind:?}: pattern mismatch");
            assert_eq!(got.row_idx, want.row_idx, "{kind:?}: pattern mismatch");
            for (a, b) in got.values.iter().zip(&want.values) {
                assert!((a - b).abs() < 1e-10, "{kind:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rejects_too_many_dims() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let rt = Runtime::open_default().unwrap();
        let asm = XlaCovarianceAssembler::new(&rt);
        let cov = CovFunction::new(CovKind::Se, DMAX + 1, 1.0, 1.0);
        let x = random_points(4, DMAX + 1, 1.0, 1);
        assert!(asm.cov_matrix(&cov, &x).is_err());
    }
}
