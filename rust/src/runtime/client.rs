//! PJRT client wrapper and executable cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

/// Artifact geometry — must match `python/compile/kernels/ref.py`
/// (`manifest.json` is checked against these at load time).
pub const TILE: usize = 128;
pub const DMAX: usize = 64;
pub const PROBIT_BATCH: usize = 1024;

/// A PJRT CPU client plus a compile-once executable cache keyed by
/// artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifact directory (expects `<name>.hlo.txt` files and a
    /// `manifest.json` as written by `python -m compile.aot`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.json");
        if manifest.exists() {
            let text = std::fs::read_to_string(&manifest)?;
            for (key, want) in
                [("\"tile\"", TILE), ("\"dmax\"", DMAX), ("\"probit_batch\"", PROBIT_BATCH)]
            {
                let got = json_usize(&text, key)
                    .ok_or_else(|| anyhow!("manifest missing {key}"))?;
                if got != want {
                    return Err(anyhow!(
                        "artifact geometry mismatch: {key} = {got}, runtime expects {want} \
                         (re-run `make artifacts`)"
                    ));
                }
            }
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir, cache: Mutex::new(HashMap::new()) })
    }

    /// Default location: `$CSGP_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("CSGP_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Runtime::open(dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (once) the named artifact.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?} (run `make artifacts`?)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp).with_context(|| format!("compiling {name}"))?);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact whose lowered signature returns a tuple; the
    /// tuple elements come back as f64 vectors.
    pub fn run_f64(
        &self,
        name: &str,
        inputs: &[(&[f64], &[i64])], // (data, dims)
    ) -> Result<Vec<Vec<f64>>> {
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| xla::Literal::vec1(data).reshape(dims))
            .collect::<std::result::Result<_, _>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        let parts = out.to_tuple()?;
        parts.into_iter().map(|l| Ok(l.to_vec::<f64>()?)).collect()
    }

    /// Batched probit tilted moments through the `probit_moments`
    /// artifact. Inputs shorter than [`PROBIT_BATCH`] are padded.
    pub fn probit_moments(
        &self,
        y: &[f64],
        mu: &[f64],
        var: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        let n = y.len();
        assert!(n <= PROBIT_BATCH && mu.len() == n && var.len() == n);
        let pad = |v: &[f64], fill: f64| {
            let mut p = v.to_vec();
            p.resize(PROBIT_BATCH, fill);
            p
        };
        let (yp, mup, varp) = (pad(y, 1.0), pad(mu, 0.0), pad(var, 1.0));
        let dims = [PROBIT_BATCH as i64];
        let mut out =
            self.run_f64("probit_moments", &[(&yp, &dims), (&mup, &dims), (&varp, &dims)])?;
        let mut s2h = out.pop().ok_or_else(|| anyhow!("missing output"))?;
        let mut muh = out.pop().ok_or_else(|| anyhow!("missing output"))?;
        let mut lnz = out.pop().ok_or_else(|| anyhow!("missing output"))?;
        lnz.truncate(n);
        muh.truncate(n);
        s2h.truncate(n);
        Ok((lnz, muh, s2h))
    }

    /// Batched predictive probabilities through the `predict_probit`
    /// artifact (handles any length by chunking).
    pub fn predict_probit(&self, mean: &[f64], var: &[f64]) -> Result<Vec<f64>> {
        assert_eq!(mean.len(), var.len());
        let mut out = Vec::with_capacity(mean.len());
        let dims = [PROBIT_BATCH as i64];
        for (mc, vc) in mean.chunks(PROBIT_BATCH).zip(var.chunks(PROBIT_BATCH)) {
            let mut mp = mc.to_vec();
            mp.resize(PROBIT_BATCH, 0.0);
            let mut vp = vc.to_vec();
            vp.resize(PROBIT_BATCH, 1.0);
            let res = self.run_f64("predict_probit", &[(&mp, &dims), (&vp, &dims)])?;
            out.extend_from_slice(&res[0][..mc.len()]);
        }
        Ok(out)
    }
}

/// Minimal "key": value extractor for the flat manifest fields.
fn json_usize(text: &str, key: &str) -> Option<usize> {
    let pos = text.find(key)?;
    let rest = &text[pos + key.len()..];
    let colon = rest.find(':')?;
    let tail = rest[colon + 1..].trim_start();
    let end = tail.find(|c: char| !c.is_ascii_digit()).unwrap_or(tail.len());
    tail[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn json_usize_extracts() {
        let t = r#"{"tile": 128, "dmax":64, "probit_batch" : 1024}"#;
        assert_eq!(json_usize(t, "\"tile\""), Some(128));
        assert_eq!(json_usize(t, "\"dmax\""), Some(64));
        assert_eq!(json_usize(t, "\"probit_batch\""), Some(1024));
        assert_eq!(json_usize(t, "\"missing\""), None);
    }

    #[test]
    fn probit_artifacts_match_native_likelihood() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let rt = Runtime::open_default().unwrap();
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let mu = vec![0.3, -1.2, 2.0, 0.0];
        let var = vec![0.8, 2.5, 0.5, 1.0];
        let (lnz, muh, s2h) = rt.probit_moments(&y, &mu, &var).unwrap();
        for i in 0..4 {
            let (l, m, s) = crate::gp::likelihood::probit_moments(y[i], mu[i], var[i]);
            assert!((lnz[i] - l).abs() < 1e-10, "lnz[{i}]: {} vs {l}", lnz[i]);
            assert!((muh[i] - m).abs() < 1e-10, "muh[{i}]: {} vs {m}", muh[i]);
            assert!((s2h[i] - s).abs() < 1e-10, "s2h[{i}]: {} vs {s}", s2h[i]);
        }
    }

    #[test]
    fn predict_probit_matches_native_and_chunks() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let rt = Runtime::open_default().unwrap();
        // longer than one batch to exercise chunking
        let n = PROBIT_BATCH + 37;
        let mean: Vec<f64> = (0..n).map(|i| (i as f64 / 100.0) - 5.0).collect();
        let var: Vec<f64> = (0..n).map(|i| 0.1 + (i % 7) as f64).collect();
        let got = rt.predict_probit(&mean, &var).unwrap();
        assert_eq!(got.len(), n);
        for i in (0..n).step_by(101) {
            let want = crate::gp::predict::class_probability(mean[i], var[i]);
            assert!((got[i] - want).abs() < 1e-10, "i={i}: {} vs {want}", got[i]);
        }
    }

    #[test]
    fn executable_cache_reuses() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let rt = Runtime::open_default().unwrap();
        let a = rt.executable("predict_probit").unwrap();
        let b = rt.executable("predict_probit").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
