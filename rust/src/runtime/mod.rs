//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text), compiles each once on the CPU PJRT
//! client, caches the executables, and exposes typed wrappers for the
//! covariance-tile and probit entry points used on the L3 hot path.
//!
//! Python never runs here — the `.hlo.txt` files are the only thing that
//! crosses the language boundary, at build time.

pub mod client;
pub mod covariance;

pub use client::{Runtime, DMAX, PROBIT_BATCH, TILE};
pub use covariance::XlaCovarianceAssembler;
