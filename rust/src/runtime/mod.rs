//! Artifact runtime for the kernels compiled by `python/compile/aot.py`
//! (covariance tiles, probit moments, predictive probabilities).
//!
//! Two backends behind one [`Runtime`] handle:
//!
//! * **native** (default) — a pure-rust interpreter of the artifact entry
//!   points, bit-compatible with the reference formulas the artifacts
//!   were generated from. No external dependencies, works offline.
//! * **pjrt** (`--features xla`) — executes the AOT-compiled HLO through
//!   a PJRT client. Requires vendored PJRT bindings; without them the
//!   feature still builds and the runtime transparently uses the native
//!   backend, so enabling `xla` is always safe.
//!
//! Python never runs here — the `.hlo.txt` files are the only thing that
//! crosses the language boundary, at build time.

pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;

pub use native::{Runtime, RuntimeBackend, DMAX, PROBIT_BATCH, TILE};
