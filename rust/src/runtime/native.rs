//! Native artifact interpreter — the default runtime backend.
//!
//! The AOT pipeline (`python/compile/aot.py`) lowers the covariance-tile
//! and probit kernels to HLO text plus a `manifest.json` describing the
//! artifact geometry. Without vendored PJRT bindings the runtime cannot
//! *execute* those artifacts, but every entry point has a bit-compatible
//! native implementation (the artifacts were generated from the same
//! reference formulas in `python/compile/kernels/ref.py`), so the rest of
//! the system — the prediction service's probability stage, the CLI's
//! `artifacts-check`, the benches — runs unchanged. The manifest is still
//! validated when present, so geometry drift is caught at open time
//! rather than at the first PJRT-enabled deployment.

use std::path::{Path, PathBuf};

use crate::gp::covariance::CovFunction;
use crate::gp::likelihood::probit_moments;
use crate::gp::predict::class_probability;
use crate::sparse::csc::CscMatrix;

/// Artifact geometry — must match `python/compile/kernels/ref.py`
/// (`manifest.json` is checked against these at load time).
pub const TILE: usize = 128;
pub const DMAX: usize = 64;
pub const PROBIT_BATCH: usize = 1024;

/// Which backend answers runtime calls.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuntimeBackend {
    /// Pure-rust interpreter of the artifact entry points (always built).
    Native,
    /// PJRT execution of the compiled artifacts (requires the `xla`
    /// feature *and* vendored PJRT bindings).
    Pjrt,
}

/// Runtime handle: artifact directory + the backend serving it.
pub struct Runtime {
    dir: PathBuf,
    backend: RuntimeBackend,
    artifacts_present: bool,
}

impl Runtime {
    /// Open the artifact directory. A `manifest.json` (as written by
    /// `python -m compile.aot`) is validated when present; a missing
    /// manifest is fine for the native backend, which needs no artifacts.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime, String> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.json");
        let artifacts_present = manifest.exists();
        if artifacts_present {
            let text = std::fs::read_to_string(&manifest).map_err(|e| e.to_string())?;
            for (key, want) in
                [("\"tile\"", TILE), ("\"dmax\"", DMAX), ("\"probit_batch\"", PROBIT_BATCH)]
            {
                let got =
                    json_usize(&text, key).ok_or_else(|| format!("manifest missing {key}"))?;
                if got != want {
                    return Err(format!(
                        "artifact geometry mismatch: {key} = {got}, runtime expects {want} \
                         (re-run `make artifacts`)"
                    ));
                }
            }
        }
        let backend = Runtime::select_backend(&dir, artifacts_present);
        Ok(Runtime { dir, backend, artifacts_present })
    }

    #[cfg(feature = "xla")]
    fn select_backend(dir: &Path, artifacts_present: bool) -> RuntimeBackend {
        if artifacts_present && crate::runtime::pjrt::bindings_available(dir) {
            RuntimeBackend::Pjrt
        } else {
            RuntimeBackend::Native
        }
    }

    #[cfg(not(feature = "xla"))]
    fn select_backend(_dir: &Path, _artifacts_present: bool) -> RuntimeBackend {
        RuntimeBackend::Native
    }

    /// Default location: `$CSGP_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Runtime, String> {
        let dir = std::env::var("CSGP_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Runtime::open(dir)
    }

    pub fn backend(&self) -> RuntimeBackend {
        self.backend
    }

    pub fn platform(&self) -> String {
        match self.backend {
            RuntimeBackend::Native => "native-interpreter".to_string(),
            RuntimeBackend::Pjrt => "pjrt-cpu".to_string(),
        }
    }

    /// Directory the runtime was opened on.
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Whether a validated artifact manifest was found.
    pub fn artifacts_present(&self) -> bool {
        self.artifacts_present
    }

    /// Batched probit tilted moments (`probit_moments` artifact).
    pub fn probit_moments(
        &self,
        y: &[f64],
        mu: &[f64],
        var: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>), String> {
        let n = y.len();
        if n > PROBIT_BATCH || mu.len() != n || var.len() != n {
            return Err(format!(
                "probit_moments: bad batch (n = {n}, mu = {}, var = {}, max = {PROBIT_BATCH})",
                mu.len(),
                var.len()
            ));
        }
        let mut lnz = Vec::with_capacity(n);
        let mut muh = Vec::with_capacity(n);
        let mut s2h = Vec::with_capacity(n);
        for i in 0..n {
            let (l, m, s) = probit_moments(y[i], mu[i], var[i]);
            lnz.push(l);
            muh.push(m);
            s2h.push(s);
        }
        Ok((lnz, muh, s2h))
    }

    /// Batched predictive probabilities (`predict_probit` artifact; any
    /// length, chunked to the artifact batch internally).
    pub fn predict_probit(&self, mean: &[f64], var: &[f64]) -> Result<Vec<f64>, String> {
        if mean.len() != var.len() {
            return Err("predict_probit: length mismatch".to_string());
        }
        Ok(mean.iter().zip(var).map(|(&m, &v)| class_probability(m, v)).collect())
    }

    /// Full covariance matrix assembly (`cov_tile_<kind>` artifacts):
    /// matches [`CovFunction::cov_matrix`] — pattern and values — exactly.
    pub fn cov_matrix(&self, cov: &CovFunction, x: &[Vec<f64>]) -> Result<CscMatrix, String> {
        let d = cov.lengthscales.len();
        if d > DMAX {
            return Err(format!("input dim {d} exceeds artifact DMAX {DMAX}"));
        }
        Ok(cov.cov_matrix(x))
    }
}

/// Minimal "key": value extractor for the flat manifest fields.
fn json_usize(text: &str, key: &str) -> Option<usize> {
    let pos = text.find(key)?;
    let rest = &text[pos + key.len()..];
    let colon = rest.find(':')?;
    let tail = rest[colon + 1..].trim_start();
    let end = tail.find(|c: char| !c.is_ascii_digit()).unwrap_or(tail.len());
    tail[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::testutil::random_points;

    #[test]
    fn json_usize_extracts() {
        let t = r#"{"tile": 128, "dmax":64, "probit_batch" : 1024}"#;
        assert_eq!(json_usize(t, "\"tile\""), Some(128));
        assert_eq!(json_usize(t, "\"dmax\""), Some(64));
        assert_eq!(json_usize(t, "\"probit_batch\""), Some(1024));
        assert_eq!(json_usize(t, "\"missing\""), None);
    }

    #[test]
    fn opens_without_artifacts_on_native_backend() {
        let rt = Runtime::open("this/dir/does/not/exist").unwrap();
        assert_eq!(rt.backend(), RuntimeBackend::Native);
        assert!(!rt.artifacts_present());
        assert_eq!(rt.platform(), "native-interpreter");
    }

    #[test]
    fn probit_moments_match_native_likelihood() {
        let rt = Runtime::open_default().unwrap();
        let y = [1.0, -1.0, 1.0, -1.0];
        let mu = [0.3, -1.2, 2.0, 0.0];
        let var = [0.8, 2.5, 0.5, 1.0];
        let (lnz, muh, s2h) = rt.probit_moments(&y, &mu, &var).unwrap();
        for i in 0..4 {
            let (l, m, s) = probit_moments(y[i], mu[i], var[i]);
            assert_eq!(lnz[i], l);
            assert_eq!(muh[i], m);
            assert_eq!(s2h[i], s);
        }
    }

    #[test]
    fn predict_probit_matches_native_any_length() {
        let rt = Runtime::open_default().unwrap();
        let n = PROBIT_BATCH + 37;
        let mean: Vec<f64> = (0..n).map(|i| (i as f64 / 100.0) - 5.0).collect();
        let var: Vec<f64> = (0..n).map(|i| 0.1 + (i % 7) as f64).collect();
        let got = rt.predict_probit(&mean, &var).unwrap();
        assert_eq!(got.len(), n);
        for i in (0..n).step_by(101) {
            assert_eq!(got[i], class_probability(mean[i], var[i]));
        }
    }

    #[test]
    fn cov_assembly_matches_native_and_checks_dim() {
        let rt = Runtime::open_default().unwrap();
        let x = random_points(150, 3, 8.0, 99);
        for kind in [CovKind::Se, CovKind::Pp(0), CovKind::Pp(3), CovKind::Matern52] {
            let mut cov = CovFunction::new(kind, 3, 1.4, 2.0);
            cov.lengthscales = vec![2.0, 1.0, 3.0];
            let got = rt.cov_matrix(&cov, &x).unwrap();
            let want = cov.cov_matrix(&x);
            assert_eq!(got, want, "{kind:?}");
        }
        let cov = CovFunction::new(CovKind::Se, DMAX + 1, 1.0, 1.0);
        let x = random_points(4, DMAX + 1, 1.0, 1);
        assert!(rt.cov_matrix(&cov, &x).is_err());
    }

    #[test]
    fn bad_manifest_geometry_is_rejected() {
        let dir = std::env::temp_dir().join(format!("csgp-rt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"tile": 64, "dmax": 64, "probit_batch": 1024}"#,
        )
        .unwrap();
        let err = Runtime::open(&dir).unwrap_err();
        assert!(err.contains("geometry mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
