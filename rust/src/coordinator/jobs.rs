//! Training-job manager: submit hyperparameter-optimization jobs, poll
//! their status, collect the fitted classifiers. A fixed worker pool
//! drains a shared queue — the coordinator pattern for the "train many
//! models" workloads of the UCI benchmark.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::data::Dataset;
use crate::gp::covariance::CovFunction;
use crate::gp::model::{FittedClassifier, GpClassifier, Inference};

/// Job identifier.
pub type JobId = u64;

/// What to train.
#[derive(Clone)]
pub struct TrainSpec {
    pub dataset: Dataset,
    pub cov: CovFunction,
    /// Global trend kernel for `Inference::CsFic` (None otherwise).
    pub global_cov: Option<CovFunction>,
    pub inference: Inference,
    /// Optimize hyperparameters (vs a single EP run).
    pub optimize: bool,
}

/// Lifecycle of a job.
#[derive(Clone, Debug, PartialEq)]
pub enum JobStatus {
    Queued,
    Running,
    Done { log_post: f64, ep_time: Duration, opt_time: Duration },
    Failed(String),
}

struct Shared {
    status: Mutex<HashMap<JobId, JobStatus>>,
    results: Mutex<HashMap<JobId, Arc<FittedClassifier>>>,
}

/// The manager handle.
pub struct JobManager {
    tx: Mutex<Option<Sender<(JobId, TrainSpec)>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    shared: Arc<Shared>,
    next_id: Mutex<JobId>,
}

impl JobManager {
    pub fn start(n_workers: usize) -> JobManager {
        let (tx, rx) = channel::<(JobId, TrainSpec)>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            status: Mutex::new(HashMap::new()),
            results: Mutex::new(HashMap::new()),
        });
        let mut workers = Vec::new();
        for _ in 0..n_workers.max(1) {
            let rx = rx.clone();
            let shared = shared.clone();
            workers.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let (id, spec) = match job {
                    Ok(j) => j,
                    Err(_) => return,
                };
                shared.status.lock().unwrap().insert(id, JobStatus::Running);
                // CS+FIC jobs go through the dedicated constructor so the
                // hyperprior covers the joint parameter vector; a global
                // kernel on any other backend is a misconfiguration (it
                // would be silently ignored), so fail the job instead
                let model = match (&spec.inference, &spec.global_cov) {
                    (Inference::CsFic { m, ordering }, Some(g)) => {
                        GpClassifier::new_cs_fic_with_ordering(
                            spec.cov.clone(),
                            g.clone(),
                            *m,
                            *ordering,
                        )
                    }
                    (_, Some(_)) => Err(format!(
                        "global_cov is only meaningful with Inference::CsFic (got {:?})",
                        spec.inference
                    )),
                    _ => Ok(GpClassifier::new(spec.cov.clone(), spec.inference.clone())),
                };
                let outcome = model.and_then(|model| {
                    if spec.optimize {
                        model.fit(&spec.dataset.x, &spec.dataset.y)
                    } else {
                        model.infer_only(&spec.dataset.x, &spec.dataset.y)
                    }
                });
                match outcome {
                    Ok(fitted) => {
                        let st = JobStatus::Done {
                            log_post: fitted.report.log_post,
                            ep_time: fitted.report.ep_time,
                            opt_time: fitted.report.opt_time,
                        };
                        shared.results.lock().unwrap().insert(id, Arc::new(fitted));
                        shared.status.lock().unwrap().insert(id, st);
                    }
                    Err(e) => {
                        shared.status.lock().unwrap().insert(id, JobStatus::Failed(e));
                    }
                }
            }));
        }
        JobManager {
            tx: Mutex::new(Some(tx)),
            workers: Mutex::new(workers),
            shared,
            next_id: Mutex::new(0),
        }
    }

    /// Enqueue a job; returns its id.
    pub fn submit(&self, spec: TrainSpec) -> Result<JobId, String> {
        let mut next = self.next_id.lock().unwrap();
        let id = *next;
        *next += 1;
        drop(next);
        self.shared.status.lock().unwrap().insert(id, JobStatus::Queued);
        let guard = self.tx.lock().unwrap();
        guard
            .as_ref()
            .ok_or("manager stopped")?
            .send((id, spec))
            .map_err(|_| "workers gone".to_string())?;
        Ok(id)
    }

    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.shared.status.lock().unwrap().get(&id).cloned()
    }

    /// Fitted model of a finished job.
    pub fn result(&self, id: JobId) -> Option<Arc<FittedClassifier>> {
        self.shared.results.lock().unwrap().get(&id).cloned()
    }

    /// Block until `id` leaves Queued/Running (or the timeout hits).
    pub fn wait(&self, id: JobId, timeout: Duration) -> Option<JobStatus> {
        let start = std::time::Instant::now();
        loop {
            match self.status(id) {
                Some(JobStatus::Queued) | Some(JobStatus::Running) => {
                    if start.elapsed() > timeout {
                        return self.status(id);
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                other => return other,
            }
        }
    }

    /// Stop accepting jobs and join the workers.
    pub fn shutdown(&self) {
        self.tx.lock().unwrap().take();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for JobManager {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::sparse::ordering::Ordering;
    use crate::testutil::random_points;

    fn toy_spec(seed: u64, optimize: bool) -> TrainSpec {
        let x = random_points(30, 2, 6.0, seed);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        TrainSpec {
            dataset: Dataset { name: format!("toy{seed}"), x, y },
            cov: CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
            global_cov: None,
            inference: Inference::Sparse(Ordering::Rcm),
            optimize,
        }
    }

    #[test]
    fn jobs_run_to_completion_in_parallel() {
        let mgr = JobManager::start(3);
        let ids: Vec<JobId> =
            (0..5).map(|s| mgr.submit(toy_spec(s, false)).unwrap()).collect();
        for id in ids {
            let st = mgr.wait(id, Duration::from_secs(30)).unwrap();
            match st {
                JobStatus::Done { log_post, .. } => assert!(log_post.is_finite()),
                other => panic!("job {id}: {other:?}"),
            }
            let fitted = mgr.result(id).unwrap();
            let (m, _) = fitted.predict_latent(&[1.0, 1.0]);
            assert!(m.is_finite());
        }
        mgr.shutdown();
    }

    #[test]
    fn unknown_job_has_no_status() {
        let mgr = JobManager::start(1);
        assert!(mgr.status(999).is_none());
        mgr.shutdown();
    }

    /// CS+FIC trains through the job manager like every other backend.
    #[test]
    fn cs_fic_jobs_train_and_serve() {
        let x = random_points(40, 2, 6.0, 9);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        let mgr = JobManager::start(1);
        let id = mgr
            .submit(TrainSpec {
                dataset: Dataset { name: "hybrid".into(), x, y },
                cov: CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
                global_cov: Some(CovFunction::new(CovKind::Se, 2, 0.6, 3.0)),
                inference: Inference::CsFic { m: 8, ordering: Ordering::Auto },
                optimize: false,
            })
            .unwrap();
        let st = mgr.wait(id, Duration::from_secs(60)).unwrap();
        assert!(matches!(st, JobStatus::Done { .. }), "{st:?}");
        let fitted = mgr.result(id).unwrap();
        let (m, v) = fitted.predict_latent(&[1.0, 1.0]);
        assert!(m.is_finite() && v > 0.0);
        mgr.shutdown();
    }

    /// A global kernel on a non-hybrid backend would be silently ignored;
    /// the job must fail loudly instead.
    #[test]
    fn global_cov_on_non_hybrid_backend_fails_the_job() {
        let mut spec = toy_spec(3, false);
        spec.global_cov = Some(CovFunction::new(CovKind::Se, 2, 1.0, 2.0));
        let mgr = JobManager::start(1);
        let id = mgr.submit(spec).unwrap();
        let st = mgr.wait(id, Duration::from_secs(30)).unwrap();
        assert!(matches!(st, JobStatus::Failed(_)), "{st:?}");
        mgr.shutdown();
    }
}
