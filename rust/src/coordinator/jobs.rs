//! Training-job manager: submit hyperparameter-optimization jobs, poll
//! their status, collect the fitted classifiers. A fixed worker pool
//! drains a shared queue — the coordinator pattern for the "train many
//! models" workloads of the UCI benchmark.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::data::Dataset;
use crate::gp::covariance::CovFunction;
use crate::gp::model::{FittedClassifier, GpClassifier, Inference};
use crate::obs;
use crate::sparse::ordering::Ordering;

/// Job identifier.
pub type JobId = u64;

/// Where in its lifecycle a job failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStage {
    /// Constructing the model from the [`TrainSpec`].
    BuildSpec,
    /// The EP run at fixed hyperparameters (`infer_only`).
    Ep,
    /// Hyperparameter optimization (`fit`: SCG over EP evaluations).
    Optimize,
    /// Persisting the fitted model to the spec's snapshot path.
    Snapshot,
}

impl JobStage {
    pub fn as_str(self) -> &'static str {
        match self {
            JobStage::BuildSpec => "build_spec",
            JobStage::Ep => "ep",
            JobStage::Optimize => "optimize",
            JobStage::Snapshot => "snapshot",
        }
    }
}

/// Why a job failed — structured so traces and callers can tell a
/// numeric pivot failure apart from a misconfigured spec without
/// grepping message strings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobErrorKind {
    /// The spec itself is invalid (e.g. a global kernel on a non-hybrid
    /// backend, a bad inducing-point count).
    BadSpec,
    /// The LDLᵀ factorization hit a non-positive pivot.
    PivotFailure,
    /// EP produced a non-positive marginal variance at some site.
    NegativeVariance,
    /// Any other numeric failure from the model layer.
    Numeric,
    /// Snapshot persistence failed (filesystem or serialization). The
    /// fitted model is still collected — only the durability step failed.
    Io,
}

impl JobErrorKind {
    pub fn as_str(self) -> &'static str {
        match self {
            JobErrorKind::BadSpec => "bad_spec",
            JobErrorKind::PivotFailure => "pivot_failure",
            JobErrorKind::NegativeVariance => "negative_variance",
            JobErrorKind::Numeric => "numeric",
            JobErrorKind::Io => "io",
        }
    }
}

/// A structured job failure: kind × stage plus the underlying message.
/// Recorded as `error_kind` / `error_stage` fields on the job's obs span.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobError {
    pub kind: JobErrorKind,
    pub stage: JobStage,
    pub message: String,
}

impl JobError {
    /// Classify a stringly error bubbling up from the model layer. Build
    /// errors are spec problems by construction; fit/infer errors are
    /// recognized by the stable phrases the solver stack uses
    /// (`cholesky.rs`'s pivot error, `ep_sparse.rs`'s variance error).
    pub fn classify(stage: JobStage, message: String) -> JobError {
        let kind = if stage == JobStage::BuildSpec {
            JobErrorKind::BadSpec
        } else if message.contains("not positive definite") {
            JobErrorKind::PivotFailure
        } else if message.contains("negative marginal variance") {
            JobErrorKind::NegativeVariance
        } else {
            JobErrorKind::Numeric
        };
        JobError { kind, stage, message }
    }
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} during {}: {}", self.kind.as_str(), self.stage.as_str(), self.message)
    }
}

impl std::error::Error for JobError {}

/// What to train.
#[derive(Clone)]
pub struct TrainSpec {
    pub dataset: Dataset,
    pub cov: CovFunction,
    /// Global trend kernel for `Inference::CsFic` (None otherwise).
    pub global_cov: Option<CovFunction>,
    pub inference: Inference,
    /// Optimize hyperparameters (vs a single EP run).
    pub optimize: bool,
    /// Persist the fitted model here after a successful fit (atomic
    /// write-then-rename; see [`crate::gp::snapshot`]). A save failure
    /// fails the job at [`JobStage::Snapshot`] but the fitted model is
    /// still collectable via [`JobManager::result`].
    pub snapshot_save: Option<std::path::PathBuf>,
}

/// Lifecycle of a job.
#[derive(Clone, Debug, PartialEq)]
pub enum JobStatus {
    Queued,
    Running,
    Done { log_post: f64, ep_time: Duration, opt_time: Duration },
    Failed(JobError),
}

struct Shared {
    status: Mutex<HashMap<JobId, JobStatus>>,
    results: Mutex<HashMap<JobId, Arc<FittedClassifier>>>,
}

/// Mutex guard that survives a poisoned lock: a panicking job worker must
/// not take the whole manager down with it — the protected maps stay
/// usable (the panicked job simply never reaches `Done`).
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Wall-clock budget for one job *including* its recovery retries, from
/// `CSGP_JOB_TIMEOUT_MS` (milliseconds; default 10 minutes). The budget
/// is checked between ladder rungs — a running EP attempt is never
/// preempted, so a timeout stops further fallbacks, not in-flight work.
fn job_timeout() -> Duration {
    static MS: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
    let ms = *MS.get_or_init(|| {
        std::env::var("CSGP_JOB_TIMEOUT_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(600_000)
    });
    Duration::from_millis(ms)
}

/// Largest `n` the degradation ladder's dense-EP fallback will accept —
/// dense EP is O(n³) per sweep, so the rung only exists for problems
/// small enough to afford it.
const DENSE_FALLBACK_MAX_N: usize = 2000;

/// Validate the training inputs before any factorization work: NaN/∞
/// coordinates, mismatched lengths, ragged dimensions, or labels outside
/// {−1, +1} fail the job as [`JobErrorKind::BadSpec`] up front instead of
/// surfacing later as a numeric error deep in the solver stack.
fn validate_spec(spec: &TrainSpec) -> Result<(), JobError> {
    let bad = |message: String| JobError {
        kind: JobErrorKind::BadSpec,
        stage: JobStage::BuildSpec,
        message,
    };
    let n = spec.dataset.x.len();
    if n == 0 {
        return Err(bad("empty dataset".into()));
    }
    if spec.dataset.y.len() != n {
        return Err(bad(format!(
            "x/y length mismatch: {n} inputs vs {} labels",
            spec.dataset.y.len()
        )));
    }
    let dim = spec.dataset.x[0].len();
    for (i, p) in spec.dataset.x.iter().enumerate() {
        if p.len() != dim {
            return Err(bad(format!(
                "input {i} has dimension {} (expected {dim})",
                p.len()
            )));
        }
        if p.iter().any(|v| !v.is_finite()) {
            return Err(bad(format!("non-finite coordinate in input {i}")));
        }
    }
    for (i, &v) in spec.dataset.y.iter().enumerate() {
        if v != 1.0 && v != -1.0 {
            return Err(bad(format!("label {i} is {v} (labels must be ±1)")));
        }
    }
    Ok(())
}

/// Run a job through the degradation ladder. The first attempt uses the
/// spec as configured; on failure, the error kind picks a bounded
/// sequence of fallbacks:
///
/// * pivot failure → retry with a deeper jitter budget and damping
/// * any other numeric failure → retry on the sequential sweep with
///   heavier damping and more sweeps (hybrid specs keep their backend —
///   dropping the global term would change the model — and only soften
///   the damping)
/// * final fallback → dense EP, for problems small enough to afford it
///
/// Bad specs never retry. Every rung taken is recorded on a `job.retry`
/// span (`rung`, `error_kind` fields) and in the `jobs.retries` counter;
/// the per-job wall-clock budget ([`job_timeout`]) is checked between
/// rungs.
fn run_with_recovery(
    spec: &TrainSpec,
    model: GpClassifier,
    stage: JobStage,
) -> Result<FittedClassifier, JobError> {
    let deadline = Instant::now() + job_timeout();
    let attempt = |m: &GpClassifier| -> Result<FittedClassifier, JobError> {
        let fitted = if spec.optimize {
            m.fit(&spec.dataset.x, &spec.dataset.y)
        } else {
            m.infer_only(&spec.dataset.x, &spec.dataset.y)
        };
        fitted.map_err(|e| JobError::classify(stage, e))
    };
    let mut err = match attempt(&model) {
        Ok(f) => return Ok(f),
        Err(e) => e,
    };
    if err.kind == JobErrorKind::BadSpec {
        return Err(err);
    }
    let mut rungs: Vec<&'static str> = Vec::new();
    if err.kind == JobErrorKind::PivotFailure {
        rungs.push("jitter");
    }
    if !matches!(model.inference, Inference::Dense) {
        rungs.push("sequential_damped");
    }
    if !matches!(model.inference, Inference::Dense)
        && model.global_cov.is_none()
        && spec.dataset.x.len() <= DENSE_FALLBACK_MAX_N
    {
        rungs.push("dense");
    }
    for rung in rungs {
        if Instant::now() >= deadline {
            err.message = format!(
                "{} (job timeout hit before the '{rung}' fallback)",
                err.message
            );
            return Err(err);
        }
        let mut m = model.clone();
        match rung {
            "jitter" => {
                m.ep_opts.max_jitter_retries = m.ep_opts.max_jitter_retries.max(40);
                m.ep_opts.damping = m.ep_opts.damping.min(0.5);
            }
            "sequential_damped" => {
                m.ep_opts.damping = (0.5 * m.ep_opts.damping).max(m.ep_opts.min_damping);
                m.ep_opts.max_sweeps *= 2;
                if m.global_cov.is_none() {
                    m.inference = Inference::Sparse(Ordering::Auto);
                }
            }
            "dense" => {
                m.inference = Inference::Dense;
                m.ep_opts.damping = m.ep_opts.damping.min(0.5);
            }
            _ => unreachable!(),
        }
        obs::counters::JOB_RETRIES.add(1);
        let mut rspan = obs::span("job.retry");
        if rspan.is_active() {
            rspan.field_str("rung", rung);
            rspan.field_str("error_kind", err.kind.as_str());
        }
        match attempt(&m) {
            Ok(f) => return Ok(f),
            Err(e) => err = e,
        }
    }
    Err(err)
}

/// The manager handle.
pub struct JobManager {
    tx: Mutex<Option<Sender<(JobId, TrainSpec)>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    shared: Arc<Shared>,
    next_id: Mutex<JobId>,
}

impl JobManager {
    pub fn start(n_workers: usize) -> JobManager {
        let (tx, rx) = channel::<(JobId, TrainSpec)>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            status: Mutex::new(HashMap::new()),
            results: Mutex::new(HashMap::new()),
        });
        let mut workers = Vec::new();
        for _ in 0..n_workers.max(1) {
            let rx = rx.clone();
            let shared = shared.clone();
            workers.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = relock(&rx);
                    guard.recv()
                };
                let (id, spec) = match job {
                    Ok(j) => j,
                    Err(_) => return,
                };
                relock(&shared.status).insert(id, JobStatus::Running);
                let track = obs::counters_on();
                let t_job = if track { Some(Instant::now()) } else { None };
                let mut jspan = obs::span("job");
                if jspan.is_active() {
                    jspan.field_u64("id", id);
                    jspan.field_bool("optimize", spec.optimize);
                }
                // CS+FIC jobs go through the dedicated constructor so the
                // hyperprior covers the joint parameter vector; a global
                // kernel on any other backend is a misconfiguration (it
                // would be silently ignored), so fail the job instead
                let model = match (&spec.inference, &spec.global_cov) {
                    (Inference::CsFic { m, ordering }, Some(g)) => {
                        GpClassifier::new_cs_fic_with_ordering(
                            spec.cov.clone(),
                            g.clone(),
                            *m,
                            *ordering,
                        )
                    }
                    (_, Some(_)) => Err(format!(
                        "global_cov is only meaningful with Inference::CsFic (got {:?})",
                        spec.inference
                    )),
                    _ => Ok(GpClassifier::new(spec.cov.clone(), spec.inference.clone())),
                };
                let fit_stage = if spec.optimize { JobStage::Optimize } else { JobStage::Ep };
                let outcome = validate_spec(&spec)
                    .and_then(|()| {
                        model.map_err(|e| JobError::classify(JobStage::BuildSpec, e))
                    })
                    .and_then(|model| run_with_recovery(&spec, model, fit_stage));
                match outcome {
                    Ok(fitted) => {
                        if let Some(t0) = t_job {
                            let hist = if spec.optimize {
                                &obs::counters::JOB_FIT_NS
                            } else {
                                &obs::counters::JOB_INFER_NS
                            };
                            hist.record(t0.elapsed());
                        }
                        // durability step: a failed save fails the job but
                        // the fitted model is still collected — callers can
                        // retry the save without re-fitting
                        let save_err = spec.snapshot_save.as_deref().and_then(|path| {
                            fitted.save_snapshot(path).err().map(|e| JobError {
                                kind: JobErrorKind::Io,
                                stage: JobStage::Snapshot,
                                message: e.to_string(),
                            })
                        });
                        let st = match &save_err {
                            None => {
                                obs::counters::JOBS_DONE.add(1);
                                if jspan.is_active() {
                                    jspan.field_str("status", "done");
                                }
                                JobStatus::Done {
                                    log_post: fitted.report.log_post,
                                    ep_time: fitted.report.ep_time,
                                    opt_time: fitted.report.opt_time,
                                }
                            }
                            Some(e) => {
                                obs::counters::JOBS_FAILED.add(1);
                                if jspan.is_active() {
                                    jspan.field_str("status", "failed");
                                    jspan.field_str("error_kind", e.kind.as_str());
                                    jspan.field_str("error_stage", e.stage.as_str());
                                }
                                JobStatus::Failed(e.clone())
                            }
                        };
                        relock(&shared.results).insert(id, Arc::new(fitted));
                        relock(&shared.status).insert(id, st);
                    }
                    Err(e) => {
                        obs::counters::JOBS_FAILED.add(1);
                        if jspan.is_active() {
                            jspan.field_str("status", "failed");
                            jspan.field_str("error_kind", e.kind.as_str());
                            jspan.field_str("error_stage", e.stage.as_str());
                        }
                        relock(&shared.status).insert(id, JobStatus::Failed(e));
                    }
                }
            }));
        }
        JobManager {
            tx: Mutex::new(Some(tx)),
            workers: Mutex::new(workers),
            shared,
            next_id: Mutex::new(0),
        }
    }

    /// Enqueue a job; returns its id.
    pub fn submit(&self, spec: TrainSpec) -> Result<JobId, String> {
        let mut next = relock(&self.next_id);
        let id = *next;
        *next += 1;
        drop(next);
        relock(&self.shared.status).insert(id, JobStatus::Queued);
        let guard = relock(&self.tx);
        guard
            .as_ref()
            .ok_or("manager stopped")?
            .send((id, spec))
            .map_err(|_| "workers gone".to_string())?;
        Ok(id)
    }

    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        relock(&self.shared.status).get(&id).cloned()
    }

    /// Fitted model of a finished job.
    pub fn result(&self, id: JobId) -> Option<Arc<FittedClassifier>> {
        relock(&self.shared.results).get(&id).cloned()
    }

    /// Block until `id` leaves Queued/Running (or the timeout hits).
    pub fn wait(&self, id: JobId, timeout: Duration) -> Option<JobStatus> {
        let start = std::time::Instant::now();
        loop {
            match self.status(id) {
                Some(JobStatus::Queued) | Some(JobStatus::Running) => {
                    if start.elapsed() > timeout {
                        return self.status(id);
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                other => return other,
            }
        }
    }

    /// Stop accepting jobs and join the workers.
    pub fn shutdown(&self) {
        relock(&self.tx).take();
        for h in relock(&self.workers).drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for JobManager {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::sparse::ordering::Ordering;
    use crate::testutil::random_points;

    fn toy_spec(seed: u64, optimize: bool) -> TrainSpec {
        let x = random_points(30, 2, 6.0, seed);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        TrainSpec {
            dataset: Dataset { name: format!("toy{seed}"), x, y },
            cov: CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
            global_cov: None,
            inference: Inference::Sparse(Ordering::Rcm),
            optimize,
            snapshot_save: None,
        }
    }

    #[test]
    fn jobs_run_to_completion_in_parallel() {
        let mgr = JobManager::start(3);
        let ids: Vec<JobId> =
            (0..5).map(|s| mgr.submit(toy_spec(s, false)).unwrap()).collect();
        for id in ids {
            let st = mgr.wait(id, Duration::from_secs(30)).unwrap();
            match st {
                JobStatus::Done { log_post, .. } => assert!(log_post.is_finite()),
                other => panic!("job {id}: {other:?}"),
            }
            let fitted = mgr.result(id).unwrap();
            let (m, _) = fitted.predict_latent(&[1.0, 1.0]);
            assert!(m.is_finite());
        }
        mgr.shutdown();
    }

    #[test]
    fn unknown_job_has_no_status() {
        let mgr = JobManager::start(1);
        assert!(mgr.status(999).is_none());
        mgr.shutdown();
    }

    /// CS+FIC trains through the job manager like every other backend.
    #[test]
    fn cs_fic_jobs_train_and_serve() {
        let x = random_points(40, 2, 6.0, 9);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        let mgr = JobManager::start(1);
        let id = mgr
            .submit(TrainSpec {
                dataset: Dataset { name: "hybrid".into(), x, y },
                cov: CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
                global_cov: Some(CovFunction::new(CovKind::Se, 2, 0.6, 3.0)),
                inference: Inference::CsFic { m: 8, ordering: Ordering::Auto },
                optimize: false,
                snapshot_save: None,
            })
            .unwrap();
        let st = mgr.wait(id, Duration::from_secs(60)).unwrap();
        assert!(matches!(st, JobStatus::Done { .. }), "{st:?}");
        let fitted = mgr.result(id).unwrap();
        let (m, v) = fitted.predict_latent(&[1.0, 1.0]);
        assert!(m.is_finite() && v > 0.0);
        mgr.shutdown();
    }

    /// Broken inputs fail up front as `BadSpec` — before any
    /// factorization work, and without taking a recovery rung.
    #[test]
    fn invalid_inputs_fail_fast_as_bad_spec() {
        let cases: Vec<(TrainSpec, &str)> = vec![
            {
                let mut s = toy_spec(1, false);
                s.dataset.x[3][0] = f64::NAN;
                (s, "non-finite")
            },
            {
                let mut s = toy_spec(2, false);
                s.dataset.y[0] = 0.5;
                (s, "labels must be")
            },
            {
                let mut s = toy_spec(3, false);
                s.dataset.y.pop();
                (s, "length mismatch")
            },
        ];
        let mgr = JobManager::start(1);
        for (spec, needle) in cases {
            let id = mgr.submit(spec).unwrap();
            let st = mgr.wait(id, Duration::from_secs(30)).unwrap();
            match st {
                JobStatus::Failed(err) => {
                    assert_eq!(err.kind, JobErrorKind::BadSpec);
                    assert_eq!(err.stage, JobStage::BuildSpec);
                    assert!(err.message.contains(needle), "{err}");
                }
                other => panic!("expected a failed job, got {other:?}"),
            }
        }
        mgr.shutdown();
    }

    /// A job with a snapshot path persists a loadable model that predicts
    /// identically to the in-memory result.
    #[test]
    fn jobs_persist_snapshots() {
        let dir = std::env::temp_dir().join("csgp-jobs-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("job-snap-{}.snap", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut spec = toy_spec(5, false);
        spec.snapshot_save = Some(path.clone());
        let mgr = JobManager::start(1);
        let id = mgr.submit(spec).unwrap();
        let st = mgr.wait(id, Duration::from_secs(30)).unwrap();
        assert!(matches!(st, JobStatus::Done { .. }), "{st:?}");
        let fitted = mgr.result(id).unwrap();
        let loaded = FittedClassifier::load_snapshot(&path).unwrap();
        let (m0, v0) = fitted.predict_latent(&[1.0, 1.0]);
        let (m1, v1) = loaded.predict_latent(&[1.0, 1.0]);
        assert_eq!(m0.to_bits(), m1.to_bits());
        assert_eq!(v0.to_bits(), v1.to_bits());
        mgr.shutdown();
        let _ = std::fs::remove_file(&path);
    }

    /// A global kernel on a non-hybrid backend would be silently ignored;
    /// the job must fail loudly instead.
    #[test]
    fn global_cov_on_non_hybrid_backend_fails_the_job() {
        let mut spec = toy_spec(3, false);
        spec.global_cov = Some(CovFunction::new(CovKind::Se, 2, 1.0, 2.0));
        let mgr = JobManager::start(1);
        let id = mgr.submit(spec).unwrap();
        let st = mgr.wait(id, Duration::from_secs(30)).unwrap();
        match st {
            JobStatus::Failed(err) => {
                assert_eq!(err.kind, JobErrorKind::BadSpec);
                assert_eq!(err.stage, JobStage::BuildSpec);
                assert!(err.message.contains("global_cov"), "{err}");
            }
            other => panic!("expected a failed job, got {other:?}"),
        }
        mgr.shutdown();
    }
}
