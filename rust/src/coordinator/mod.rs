//! L3 coordinator: a training-job manager and a batching prediction
//! service, built on std threads + channels (the environment vendors no
//! async runtime — see DESIGN.md §Substitutions).
//!
//! The serving path is: client → [`service::PredictionService`] →
//! dynamic batcher (size/deadline) → sparse latent prediction (rust) →
//! `predict_probit` PJRT artifact (XLA) → response. Python is never
//! involved.

pub mod jobs;
pub mod service;

pub use jobs::{JobError, JobErrorKind, JobId, JobManager, JobStage, JobStatus, TrainSpec};
pub use service::{
    flush_all_exporters, metrics_interval_from_env, MetricsExporter, PredictionService,
    ServiceConfig, ServiceError, ServiceStats,
};
