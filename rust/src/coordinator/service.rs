//! Batching prediction service.
//!
//! Requests (feature vectors) are queued on a channel; a worker thread
//! drains them into batches bounded by `max_batch` and `max_wait`, runs
//! the latent prediction through the fitted sparse-EP state, pushes the
//! batch through the `predict_probit` XLA artifact when a runtime is
//! attached (falling back to the native probit otherwise), and answers
//! each caller on its private response channel.
//!
//! Admission is bounded: at most `queue_capacity` requests may be in
//! flight (queued or computing); beyond that `predict` fails fast with
//! [`ServiceError::Overloaded`] instead of letting the queue grow without
//! limit — callers see backpressure, not unbounded latency. Per-request
//! and per-batch latencies are sampled into [`ServiceStats`]
//! ([`ServiceStats::request_latency_stats`] /
//! [`ServiceStats::batch_latency_stats`] summarize them as
//! p50/p90/p99), feeding `BENCH_serving.json` and capacity planning.

use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::gp::model::FittedClassifier;
use crate::gp::predict::class_probability;
use crate::obs;
use crate::runtime::Runtime;

/// Why [`PredictionService::predict`] failed — lifecycle errors only
/// (the compute path itself is infallible once a request is accepted).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// `shutdown` already ran; no new requests are accepted.
    Stopped,
    /// The worker thread is gone (its receiver hung up).
    WorkerGone,
    /// The worker dropped the request without replying.
    RequestDropped,
    /// The handle's sender lock was poisoned by a panicking caller.
    Poisoned,
    /// Admission refused: `queue_capacity` requests are already in
    /// flight. Back off and retry — nothing was enqueued.
    Overloaded,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let msg = match self {
            ServiceError::Stopped => "service stopped",
            ServiceError::WorkerGone => "service worker gone",
            ServiceError::RequestDropped => "service dropped request",
            ServiceError::Poisoned => "service handle poisoned",
            ServiceError::Overloaded => "service overloaded (queue full)",
        };
        f.write_str(msg)
    }
}

impl std::error::Error for ServiceError {}

/// Service tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Admission bound: maximum requests in flight (queued or computing)
    /// before `predict` rejects with [`ServiceError::Overloaded`].
    pub queue_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_capacity: 1024,
        }
    }
}

/// One prediction answer.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub probability: f64,
    pub latent_mean: f64,
    pub latent_var: f64,
    /// Time spent inside the service (queue + compute).
    pub service_time: Duration,
}

struct Request {
    x: Vec<f64>,
    enqueued: Instant,
    reply: Sender<Prediction>,
}

/// How many latency samples each buffer retains (admission keeps the
/// in-flight set small, so the first 64k samples characterize the run).
const LATENCY_SAMPLE_CAP: usize = 65_536;

/// Aggregate counters (lock-free reads) plus bounded latency sample
/// buffers for the percentile summaries.
#[derive(Default)]
pub struct ServiceStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items_max: AtomicU64,
    /// Requests refused at admission ([`ServiceError::Overloaded`]).
    pub rejected: AtomicU64,
    /// Admitted but not yet answered (the admission gate's level).
    in_flight: AtomicU64,
    request_latencies: Mutex<Vec<Duration>>,
    batch_latencies: Mutex<Vec<Duration>>,
}

impl ServiceStats {
    fn record(buf: &Mutex<Vec<Duration>>, d: Duration) {
        let mut g = buf.lock().unwrap_or_else(|e| e.into_inner());
        if g.len() < LATENCY_SAMPLE_CAP {
            g.push(d);
        }
    }

    /// p50/p90/p99 (and friends) over the sampled per-request service
    /// times (queue + compute). `None` before the first answer.
    pub fn request_latency_stats(&self) -> Option<crate::bench::Stats> {
        let g = self.request_latencies.lock().unwrap_or_else(|e| e.into_inner());
        if g.is_empty() {
            None
        } else {
            Some(crate::bench::Stats::from_samples(g.clone()))
        }
    }

    /// p50/p90/p99 (and friends) over the sampled per-batch compute
    /// times. `None` before the first batch.
    pub fn batch_latency_stats(&self) -> Option<crate::bench::Stats> {
        let g = self.batch_latencies.lock().unwrap_or_else(|e| e.into_inner());
        if g.is_empty() {
            None
        } else {
            Some(crate::bench::Stats::from_samples(g.clone()))
        }
    }
}

/// Handle to a running service.
pub struct PredictionService {
    tx: Mutex<Option<Sender<Request>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    queue_capacity: usize,
    pub stats: Arc<ServiceStats>,
}

impl PredictionService {
    /// Spawn the worker. `artifact_dir` enables the XLA probit stage; the
    /// worker opens its own PJRT client there (the xla crate's handles are
    /// not `Send`, so the runtime must live on the worker thread).
    pub fn start(
        model: Arc<FittedClassifier>,
        artifact_dir: Option<std::path::PathBuf>,
        config: ServiceConfig,
    ) -> PredictionService {
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(ServiceStats::default());
        let stats_w = stats.clone();
        let worker = std::thread::spawn(move || {
            let runtime = artifact_dir.and_then(|d| Runtime::open(d).ok());
            serve_loop(rx, model, runtime, config, stats_w);
        });
        PredictionService {
            tx: Mutex::new(Some(tx)),
            worker: Mutex::new(Some(worker)),
            queue_capacity: config.queue_capacity,
            stats,
        }
    }

    /// Submit one request and wait for the answer. Fails fast with
    /// [`ServiceError::Overloaded`] when `queue_capacity` requests are
    /// already in flight — backpressure instead of unbounded queueing.
    pub fn predict(&self, x: Vec<f64>) -> Result<Prediction, ServiceError> {
        // admission gate: reserve a slot or reject without enqueueing
        if self.stats.in_flight.fetch_add(1, AtomicOrdering::AcqRel)
            >= self.queue_capacity as u64
        {
            self.stats.in_flight.fetch_sub(1, AtomicOrdering::AcqRel);
            self.stats.rejected.fetch_add(1, AtomicOrdering::Relaxed);
            obs::counters::SVC_REJECTED.add(1);
            return Err(ServiceError::Overloaded);
        }
        // the slot is held until this request is answered (or fails), on
        // every exit path below
        struct Slot<'a>(&'a ServiceStats);
        impl Drop for Slot<'_> {
            fn drop(&mut self) {
                self.0.in_flight.fetch_sub(1, AtomicOrdering::AcqRel);
            }
        }
        let _slot = Slot(&self.stats);

        let (reply_tx, reply_rx) = channel();
        {
            let guard = self.tx.lock().map_err(|_| ServiceError::Poisoned)?;
            let tx = guard.as_ref().ok_or(ServiceError::Stopped)?;
            tx.send(Request { x, enqueued: Instant::now(), reply: reply_tx })
                .map_err(|_| ServiceError::WorkerGone)?;
        }
        let pred = reply_rx.recv().map_err(|_| ServiceError::RequestDropped)?;
        obs::counters::SVC_REQUEST_NS.record(pred.service_time);
        ServiceStats::record(&self.stats.request_latencies, pred.service_time);
        Ok(pred)
    }

    /// Drain and stop the worker. Poisoned handle locks are recovered
    /// (`into_inner`) — shutdown must make progress even after a caller
    /// panicked inside `predict`.
    pub fn shutdown(&self) {
        self.tx.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(h) = self.worker.lock().unwrap_or_else(|e| e.into_inner()).take() {
            let _ = h.join();
        }
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_loop(
    rx: Receiver<Request>,
    model: Arc<FittedClassifier>,
    runtime: Option<Runtime>,
    config: ServiceConfig,
    stats: Arc<ServiceStats>,
) {
    // one predictor for the worker's lifetime: the neighbor index over the
    // training inputs and the sparse-solve workspace are shared by every
    // batch instead of rebuilt per request (large batches fan their
    // solves out over the worker pool)
    let mut predictor = model.predictor();
    loop {
        // block for the first request of a batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + config.max_wait;
        while batch.len() < config.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.requests.fetch_add(batch.len() as u64, AtomicOrdering::Relaxed);
        stats.batches.fetch_add(1, AtomicOrdering::Relaxed);
        stats
            .batched_items_max
            .fetch_max(batch.len() as u64, AtomicOrdering::Relaxed);
        // span covers the compute only — the batching wait above is the
        // deadline's business, not the predictor's
        let t_batch = Instant::now();
        let mut bspan = obs::span("svc.batch");
        if bspan.is_active() {
            bspan.field_u64("size", batch.len() as u64);
        }

        // latent predictions: the batch's sparse solves fan out over the
        // worker pool (forked workspaces sharing the predictor's neighbor
        // index), identical to per-request serial calls; inputs move out
        // of the requests (they are not needed for the replies)
        let xs: Vec<Vec<f64>> =
            batch.iter_mut().map(|r| std::mem::take(&mut r.x)).collect();
        let latents: Vec<(f64, f64)> = predictor.predict_latent_batch(&xs);
        // probability stage: XLA artifact if available, else native probit
        let probs: Vec<f64> = match &runtime {
            Some(rt) => {
                let means: Vec<f64> = latents.iter().map(|l| l.0).collect();
                let vars: Vec<f64> = latents.iter().map(|l| l.1).collect();
                match rt.predict_probit(&means, &vars) {
                    Ok(p) => p,
                    Err(_) => latents.iter().map(|&(m, v)| class_probability(m, v)).collect(),
                }
            }
            None => latents.iter().map(|&(m, v)| class_probability(m, v)).collect(),
        };
        let batch_time = t_batch.elapsed();
        obs::counters::SVC_BATCH_NS.record(batch_time);
        ServiceStats::record(&stats.batch_latencies, batch_time);
        drop(bspan);
        for ((req, (m, v)), p) in batch.into_iter().zip(latents).zip(probs) {
            let _ = req.reply.send(Prediction {
                probability: p,
                latent_mean: m,
                latent_var: v,
                service_time: req.enqueued.elapsed(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::{CovFunction, CovKind};
    use crate::gp::model::{GpClassifier, Inference};
    use crate::sparse::ordering::Ordering;
    use crate::testutil::random_points;

    fn fitted_toy() -> Arc<FittedClassifier> {
        let x = random_points(40, 2, 6.0, 2);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        let model = GpClassifier::new(
            CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
            Inference::Sparse(Ordering::Rcm),
        );
        Arc::new(model.infer_only(&x, &y).unwrap())
    }

    #[test]
    fn serves_requests_and_batches() {
        let model = fitted_toy();
        let svc = Arc::new(PredictionService::start(
            model.clone(),
            None,
            ServiceConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(5),
                ..ServiceConfig::default()
            },
        ));
        // concurrent clients
        let mut handles = Vec::new();
        for t in 0..8 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                let mut preds = Vec::new();
                for i in 0..10 {
                    let x = vec![(t as f64) * 0.7, (i as f64) * 0.5];
                    preds.push(svc.predict(x).unwrap());
                }
                preds
            }));
        }
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len(), 80);
        assert!(all.iter().all(|p| (0.0..=1.0).contains(&p.probability)));
        assert_eq!(svc.stats.requests.load(AtomicOrdering::Relaxed), 80);
        let batches = svc.stats.batches.load(AtomicOrdering::Relaxed);
        assert!(batches <= 80, "batching never engaged: {batches}");
        svc.shutdown();
    }

    #[test]
    fn predictions_match_direct_model_calls() {
        let model = fitted_toy();
        let svc = PredictionService::start(model.clone(), None, ServiceConfig::default());
        for x in [vec![1.0, 1.0], vec![4.0, 2.0], vec![3.0, 5.5]] {
            let served = svc.predict(x.clone()).unwrap();
            let (m, v) = model.predict_latent(&x);
            assert!((served.latent_mean - m).abs() < 1e-12);
            assert!((served.latent_var - v).abs() < 1e-12);
            assert!((served.probability - class_probability(m, v)).abs() < 1e-12);
        }
        svc.shutdown();
    }

    fn fitted_cs_fic_toy() -> Arc<FittedClassifier> {
        let x = random_points(80, 2, 6.0, 11);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        let model = GpClassifier::new_cs_fic(
            CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
            CovFunction::new(CovKind::Se, 2, 0.6, 3.0),
            8,
        )
        .unwrap();
        Arc::new(model.infer_only(&x, &y).unwrap())
    }

    /// CS+FIC fits take the runtime's batched probit stage like sparse
    /// fits: the service is started *with* an artifact directory (the
    /// runtime falls back to its native interpreter when no manifest is
    /// present), so the probability column flows through
    /// `Runtime::predict_probit` — and must equal the native closed form.
    #[test]
    fn cs_fic_service_batches_probit_through_the_runtime() {
        let model = fitted_cs_fic_toy();
        let svc = PredictionService::start(
            model.clone(),
            Some(std::env::temp_dir().join("csgp-no-artifacts")),
            ServiceConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(5),
                ..ServiceConfig::default()
            },
        );
        for x in [vec![1.0, 1.0], vec![4.0, 2.0], vec![2.5, 5.0]] {
            let served = svc.predict(x.clone()).unwrap();
            let (m, v) = model.predict_latent(&x);
            assert!((served.latent_mean - m).abs() < 1e-12);
            assert!((served.latent_var - v).abs() < 1e-12);
            assert!((served.probability - class_probability(m, v)).abs() < 1e-12);
        }
        svc.shutdown();
    }

    #[test]
    fn shutdown_is_clean_and_idempotent() {
        let svc = PredictionService::start(fitted_toy(), None, ServiceConfig::default());
        svc.shutdown();
        svc.shutdown();
        assert!(svc.predict(vec![0.0, 0.0]).is_err());
    }

    /// Capacity 0 admits nothing: every request is rejected with the
    /// typed `Overloaded` error before touching the queue, and the
    /// rejection counter tracks them.
    #[test]
    fn zero_capacity_rejects_with_backpressure() {
        let svc = PredictionService::start(
            fitted_toy(),
            None,
            ServiceConfig { queue_capacity: 0, ..ServiceConfig::default() },
        );
        for _ in 0..5 {
            let err = svc.predict(vec![1.0, 1.0]).map(|_| ()).unwrap_err();
            assert_eq!(err, ServiceError::Overloaded);
        }
        assert_eq!(svc.stats.rejected.load(AtomicOrdering::Relaxed), 5);
        assert_eq!(svc.stats.requests.load(AtomicOrdering::Relaxed), 0);
        // rejection leaks no slots: raising nothing, in_flight is back to 0
        assert_eq!(svc.stats.in_flight.load(AtomicOrdering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn latency_percentiles_are_sampled() {
        let model = fitted_toy();
        let svc = PredictionService::start(model, None, ServiceConfig::default());
        assert!(svc.stats.request_latency_stats().is_none());
        for i in 0..12 {
            svc.predict(vec![i as f64 * 0.3, 1.0]).unwrap();
        }
        let req = svc.stats.request_latency_stats().expect("request samples");
        assert_eq!(req.iters, 12);
        assert!(req.p50 <= req.p90 && req.p90 <= req.p99);
        let bat = svc.stats.batch_latency_stats().expect("batch samples");
        assert!(bat.iters >= 1);
        assert!(bat.p99 >= bat.p50);
        svc.shutdown();
    }
}
