//! Batching prediction service.
//!
//! Requests (feature vectors) are queued on a channel; a worker thread
//! drains them into batches bounded by `max_batch` and `max_wait`, runs
//! the latent prediction through the fitted sparse-EP state, pushes the
//! batch through the `predict_probit` XLA artifact when a runtime is
//! attached (falling back to the native probit otherwise), and answers
//! each caller on its private response channel.
//!
//! Admission is bounded: at most `queue_capacity` requests may be in
//! flight (queued or computing); beyond that `predict` fails fast with
//! [`ServiceError::Overloaded`] instead of letting the queue grow without
//! limit — callers see backpressure, not unbounded latency. Per-request
//! and per-batch latencies are sampled into [`ServiceStats`]
//! ([`ServiceStats::request_latency_stats`] /
//! [`ServiceStats::batch_latency_stats`] summarize them as
//! p50/p90/p99), feeding `BENCH_serving.json` and capacity planning.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering as AtomicOrdering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::gp::model::FittedClassifier;
use crate::gp::predict::class_probability;
use crate::obs;
use crate::runtime::Runtime;

/// Why [`PredictionService::predict`] failed — lifecycle errors only
/// (the compute path itself is infallible once a request is accepted).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// `shutdown` already ran; no new requests are accepted.
    Stopped,
    /// The worker thread is gone (its receiver hung up).
    WorkerGone,
    /// The worker dropped the request without replying.
    RequestDropped,
    /// The handle's sender lock was poisoned by a panicking caller.
    Poisoned,
    /// Admission refused: `queue_capacity` requests are already in
    /// flight. Back off and retry — nothing was enqueued.
    Overloaded,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let msg = match self {
            ServiceError::Stopped => "service stopped",
            ServiceError::WorkerGone => "service worker gone",
            ServiceError::RequestDropped => "service dropped request",
            ServiceError::Poisoned => "service handle poisoned",
            ServiceError::Overloaded => "service overloaded (queue full)",
        };
        f.write_str(msg)
    }
}

impl std::error::Error for ServiceError {}

/// Service tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Admission bound: maximum requests in flight (queued or computing)
    /// before `predict` rejects with [`ServiceError::Overloaded`].
    pub queue_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_capacity: 1024,
        }
    }
}

/// One prediction answer.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub probability: f64,
    pub latent_mean: f64,
    pub latent_var: f64,
    /// Time spent inside the service (queue + compute).
    pub service_time: Duration,
}

struct Request {
    x: Vec<f64>,
    enqueued: Instant,
    reply: Sender<Prediction>,
}

/// How many latency samples each buffer retains (admission keeps the
/// in-flight set small, so the first 64k samples characterize the run).
const LATENCY_SAMPLE_CAP: usize = 65_536;

/// Aggregate counters (lock-free reads) plus bounded latency sample
/// buffers for the percentile summaries.
#[derive(Default)]
pub struct ServiceStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items_max: AtomicU64,
    /// Requests refused at admission ([`ServiceError::Overloaded`]).
    pub rejected: AtomicU64,
    /// Admitted but not yet answered (the admission gate's level).
    in_flight: AtomicU64,
    request_latencies: Mutex<Vec<Duration>>,
    batch_latencies: Mutex<Vec<Duration>>,
}

impl ServiceStats {
    fn record(buf: &Mutex<Vec<Duration>>, d: Duration) {
        let mut g = buf.lock().unwrap_or_else(|e| e.into_inner());
        if g.len() < LATENCY_SAMPLE_CAP {
            g.push(d);
        }
    }

    /// p50/p90/p99 (and friends) over the sampled per-request service
    /// times (queue + compute). `None` before the first answer.
    pub fn request_latency_stats(&self) -> Option<crate::bench::Stats> {
        let g = self.request_latencies.lock().unwrap_or_else(|e| e.into_inner());
        if g.is_empty() {
            None
        } else {
            Some(crate::bench::Stats::from_samples(g.clone()))
        }
    }

    /// p50/p90/p99 (and friends) over the sampled per-batch compute
    /// times. `None` before the first batch.
    pub fn batch_latency_stats(&self) -> Option<crate::bench::Stats> {
        let g = self.batch_latencies.lock().unwrap_or_else(|e| e.into_inner());
        if g.is_empty() {
            None
        } else {
            Some(crate::bench::Stats::from_samples(g.clone()))
        }
    }

    /// Current admission-gate level: requests admitted but not yet
    /// answered (queued or computing). The metrics exporter samples this.
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(AtomicOrdering::Relaxed)
    }
}

/// Handle to a running service.
pub struct PredictionService {
    tx: Mutex<Option<Sender<Request>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    queue_capacity: usize,
    pub stats: Arc<ServiceStats>,
}

impl PredictionService {
    /// Spawn the worker. `artifact_dir` enables the XLA probit stage; the
    /// worker opens its own PJRT client there (the xla crate's handles are
    /// not `Send`, so the runtime must live on the worker thread).
    pub fn start(
        model: Arc<FittedClassifier>,
        artifact_dir: Option<std::path::PathBuf>,
        config: ServiceConfig,
    ) -> PredictionService {
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(ServiceStats::default());
        let stats_w = stats.clone();
        let worker = std::thread::spawn(move || {
            let runtime = artifact_dir.and_then(|d| Runtime::open(d).ok());
            serve_loop(rx, model, runtime, config, stats_w);
        });
        PredictionService {
            tx: Mutex::new(Some(tx)),
            worker: Mutex::new(Some(worker)),
            queue_capacity: config.queue_capacity,
            stats,
        }
    }

    /// Submit one request and wait for the answer. Fails fast with
    /// [`ServiceError::Overloaded`] when `queue_capacity` requests are
    /// already in flight — backpressure instead of unbounded queueing.
    pub fn predict(&self, x: Vec<f64>) -> Result<Prediction, ServiceError> {
        // admission gate: reserve a slot or reject without enqueueing
        if self.stats.in_flight.fetch_add(1, AtomicOrdering::AcqRel)
            >= self.queue_capacity as u64
        {
            self.stats.in_flight.fetch_sub(1, AtomicOrdering::AcqRel);
            self.stats.rejected.fetch_add(1, AtomicOrdering::Relaxed);
            obs::counters::SVC_REJECTED.add(1);
            return Err(ServiceError::Overloaded);
        }
        // the slot is held until this request is answered (or fails), on
        // every exit path below
        struct Slot<'a>(&'a ServiceStats);
        impl Drop for Slot<'_> {
            fn drop(&mut self) {
                self.0.in_flight.fetch_sub(1, AtomicOrdering::AcqRel);
            }
        }
        let _slot = Slot(&self.stats);

        let (reply_tx, reply_rx) = channel();
        {
            let guard = self.tx.lock().map_err(|_| ServiceError::Poisoned)?;
            let tx = guard.as_ref().ok_or(ServiceError::Stopped)?;
            tx.send(Request { x, enqueued: Instant::now(), reply: reply_tx })
                .map_err(|_| ServiceError::WorkerGone)?;
        }
        let pred = reply_rx.recv().map_err(|_| ServiceError::RequestDropped)?;
        obs::counters::SVC_REQUEST_NS.record(pred.service_time);
        ServiceStats::record(&self.stats.request_latencies, pred.service_time);
        Ok(pred)
    }

    /// Drain and stop the worker. Poisoned handle locks are recovered
    /// (`into_inner`) — shutdown must make progress even after a caller
    /// panicked inside `predict`.
    pub fn shutdown(&self) {
        self.tx.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(h) = self.worker.lock().unwrap_or_else(|e| e.into_inner()).take() {
            let _ = h.join();
        }
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_loop(
    rx: Receiver<Request>,
    model: Arc<FittedClassifier>,
    runtime: Option<Runtime>,
    config: ServiceConfig,
    stats: Arc<ServiceStats>,
) {
    // one predictor for the worker's lifetime: the neighbor index over the
    // training inputs and the sparse-solve workspace are shared by every
    // batch instead of rebuilt per request (large batches fan their
    // solves out over the worker pool)
    let mut predictor = model.predictor();
    loop {
        // block for the first request of a batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + config.max_wait;
        while batch.len() < config.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.requests.fetch_add(batch.len() as u64, AtomicOrdering::Relaxed);
        stats.batches.fetch_add(1, AtomicOrdering::Relaxed);
        stats
            .batched_items_max
            .fetch_max(batch.len() as u64, AtomicOrdering::Relaxed);
        // span covers the compute only — the batching wait above is the
        // deadline's business, not the predictor's
        let t_batch = Instant::now();
        let mut bspan = obs::span("svc.batch");
        if bspan.is_active() {
            bspan.field_u64("size", batch.len() as u64);
        }

        // latent predictions: the batch's sparse solves fan out over the
        // worker pool (forked workspaces sharing the predictor's neighbor
        // index), identical to per-request serial calls; inputs move out
        // of the requests (they are not needed for the replies)
        let xs: Vec<Vec<f64>> =
            batch.iter_mut().map(|r| std::mem::take(&mut r.x)).collect();
        let latents: Vec<(f64, f64)> = predictor.predict_latent_batch(&xs);
        // probability stage: XLA artifact if available, else native probit
        let probs: Vec<f64> = match &runtime {
            Some(rt) => {
                let means: Vec<f64> = latents.iter().map(|l| l.0).collect();
                let vars: Vec<f64> = latents.iter().map(|l| l.1).collect();
                match rt.predict_probit(&means, &vars) {
                    Ok(p) => p,
                    Err(_) => latents.iter().map(|&(m, v)| class_probability(m, v)).collect(),
                }
            }
            None => latents.iter().map(|&(m, v)| class_probability(m, v)).collect(),
        };
        let batch_time = t_batch.elapsed();
        obs::counters::SVC_BATCH_NS.record(batch_time);
        ServiceStats::record(&stats.batch_latencies, batch_time);
        drop(bspan);
        for ((req, (m, v)), p) in batch.into_iter().zip(latents).zip(probs) {
            let _ = req.reply.send(Prediction {
                probability: p,
                latent_mean: m,
                latent_var: v,
                service_time: req.enqueued.elapsed(),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Metrics exporter.
// ---------------------------------------------------------------------------

/// Exporter cadence: `CSGP_METRICS_INTERVAL_MS` (milliseconds), default
/// 1000.
pub fn metrics_interval_from_env() -> Duration {
    std::env::var("CSGP_METRICS_INTERVAL_MS")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_millis(1000))
}

struct ExporterState {
    seq: u64,
    /// Previous counter snapshot, for the per-interval `delta` object.
    prev: Option<obs::Snapshot>,
    file: std::fs::File,
}

struct ExporterInner {
    interval: Duration,
    stats: Option<Arc<ServiceStats>>,
    stop: AtomicBool,
    state: Mutex<ExporterState>,
}

impl ExporterInner {
    /// Append one `{"ev":"metrics",...}` JSONL line: monotone `t_ns`
    /// (trace-epoch clock, so lines interleave meaningfully with span
    /// events), wall-clock `unix_ms`, admission state and latency
    /// percentiles from [`ServiceStats`], the pool-chunk histogram tail
    /// (exact min/max via `obs::hist`), the full counter snapshot, and
    /// the nonzero counter deltas since the previous line.
    fn write_snapshot(&self, final_line: bool) -> std::io::Result<()> {
        use std::fmt::Write as _;
        use std::io::Write as _;
        let snap = obs::snapshot();
        let mut state = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let seq = state.seq;
        state.seq += 1;
        let delta = state.prev.map(|p| snap.delta(&p)).unwrap_or(snap);
        state.prev = Some(snap);
        let unix_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let mut line = String::with_capacity(1024);
        let _ = write!(
            line,
            "{{\"ev\":\"metrics\",\"seq\":{seq},\"t_ns\":{},\"unix_ms\":{unix_ms}",
            obs::now_ns()
        );
        if let Some(stats) = &self.stats {
            let _ = write!(
                line,
                ",\"in_flight\":{},\"requests\":{},\"batches\":{},\
                 \"batched_items_max\":{},\"rejected\":{}",
                stats.in_flight(),
                stats.requests.load(AtomicOrdering::Relaxed),
                stats.batches.load(AtomicOrdering::Relaxed),
                stats.batched_items_max.load(AtomicOrdering::Relaxed),
                stats.rejected.load(AtomicOrdering::Relaxed)
            );
            if let Some(r) = stats.request_latency_stats() {
                let _ = write!(
                    line,
                    ",\"request_p50_ns\":{},\"request_p90_ns\":{},\"request_p99_ns\":{}",
                    r.p50.as_nanos(),
                    r.p90.as_nanos(),
                    r.p99.as_nanos()
                );
            }
            if let Some(b) = stats.batch_latency_stats() {
                let _ = write!(
                    line,
                    ",\"batch_p50_ns\":{},\"batch_p99_ns\":{}",
                    b.p50.as_nanos(),
                    b.p99.as_nanos()
                );
            }
        }
        let chunk_hist = &obs::counters::POOL_CHUNK_NS;
        if chunk_hist.count() > 0 {
            let _ = write!(
                line,
                ",\"pool_chunk_p50_ns\":{},\"pool_chunk_p99_ns\":{},\
                 \"pool_chunk_min_ns\":{},\"pool_chunk_max_ns\":{}",
                chunk_hist.percentile_ns(50.0),
                chunk_hist.percentile_ns(99.0),
                chunk_hist.min_ns(),
                chunk_hist.max_ns()
            );
        }
        line.push_str(",\"counters\":{");
        for (i, (k, v)) in snap.fields().iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            let _ = write!(line, "\"{k}\":{v}");
        }
        line.push_str("},\"delta\":{");
        let mut first = true;
        for (k, v) in delta.fields() {
            if v == 0 {
                continue;
            }
            if !first {
                line.push(',');
            }
            first = false;
            let _ = write!(line, "\"{k}\":{v}");
        }
        line.push('}');
        if final_line {
            line.push_str(",\"final\":true");
        }
        line.push_str("}\n");
        state.file.write_all(line.as_bytes())?;
        state.file.flush()
    }
}

/// Every live exporter, so shutdown paths (`flush_all_exporters`, the
/// CLI's SIGINT handler) can force a final snapshot without owning the
/// handles.
static EXPORTERS: Mutex<Vec<Weak<ExporterInner>>> = Mutex::new(Vec::new());

/// Write a final snapshot through every live [`MetricsExporter`] — the
/// SIGINT/shutdown path, so an interrupted server's metrics file still
/// ends with its last state.
pub fn flush_all_exporters() {
    let list: Vec<Weak<ExporterInner>> =
        EXPORTERS.lock().unwrap_or_else(|e| e.into_inner()).clone();
    for weak in list {
        if let Some(inner) = weak.upgrade() {
            let _ = inner.write_snapshot(true);
        }
    }
}

/// Periodic JSONL metrics exporter (`serve --metrics <path>` /
/// `CSGP_METRICS_INTERVAL_MS`): a background thread appends one
/// timestamped snapshot line per interval — counters, admission state,
/// latency percentiles — so a long-running server is inspectable without
/// full span tracing. One line is written immediately on start and one on
/// [`stop`](MetricsExporter::stop) (or drop), so even short runs
/// round-trip through `csgp trace analyze`.
pub struct MetricsExporter {
    inner: Arc<ExporterInner>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl MetricsExporter {
    /// Create/truncate `path` and start the ticker. Bumps the trace mode
    /// to Counters when it is Off (never downgrades Full): an exporter
    /// whose counters cannot move would report a flatline.
    pub fn start(
        path: impl AsRef<std::path::Path>,
        interval: Duration,
        stats: Option<Arc<ServiceStats>>,
    ) -> std::io::Result<MetricsExporter> {
        let file = std::fs::File::create(path.as_ref())?;
        if !obs::counters_on() {
            obs::set_mode(obs::TraceMode::Counters);
        }
        let inner = Arc::new(ExporterInner {
            interval,
            stats,
            stop: AtomicBool::new(false),
            state: Mutex::new(ExporterState { seq: 0, prev: None, file }),
        });
        inner.write_snapshot(false)?;
        {
            let mut reg = EXPORTERS.lock().unwrap_or_else(|e| e.into_inner());
            reg.retain(|w| w.strong_count() > 0);
            reg.push(Arc::downgrade(&inner));
        }
        let worker = inner.clone();
        let thread = std::thread::spawn(move || {
            // poll in small steps so stop() never waits a full interval
            let tick = worker
                .interval
                .min(Duration::from_millis(20))
                .max(Duration::from_millis(1));
            let mut next = Instant::now() + worker.interval;
            while !worker.stop.load(AtomicOrdering::Relaxed) {
                std::thread::sleep(tick);
                if Instant::now() >= next {
                    let _ = worker.write_snapshot(false);
                    next += worker.interval;
                }
            }
        });
        Ok(MetricsExporter { inner, thread: Mutex::new(Some(thread)) })
    }

    /// Stop the ticker and write one final snapshot (idempotent; also
    /// runs on drop).
    pub fn stop(&self) {
        self.inner.stop.store(true, AtomicOrdering::Relaxed);
        let handle = self.thread.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(h) = handle {
            let _ = h.join();
            let _ = self.inner.write_snapshot(true);
        }
    }
}

impl Drop for MetricsExporter {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::{CovFunction, CovKind};
    use crate::gp::model::{GpClassifier, Inference};
    use crate::sparse::ordering::Ordering;
    use crate::testutil::random_points;

    fn fitted_toy() -> Arc<FittedClassifier> {
        let x = random_points(40, 2, 6.0, 2);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        let model = GpClassifier::new(
            CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
            Inference::Sparse(Ordering::Rcm),
        );
        Arc::new(model.infer_only(&x, &y).unwrap())
    }

    #[test]
    fn serves_requests_and_batches() {
        let model = fitted_toy();
        let svc = Arc::new(PredictionService::start(
            model.clone(),
            None,
            ServiceConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(5),
                ..ServiceConfig::default()
            },
        ));
        // concurrent clients
        let mut handles = Vec::new();
        for t in 0..8 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                let mut preds = Vec::new();
                for i in 0..10 {
                    let x = vec![(t as f64) * 0.7, (i as f64) * 0.5];
                    preds.push(svc.predict(x).unwrap());
                }
                preds
            }));
        }
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len(), 80);
        assert!(all.iter().all(|p| (0.0..=1.0).contains(&p.probability)));
        assert_eq!(svc.stats.requests.load(AtomicOrdering::Relaxed), 80);
        let batches = svc.stats.batches.load(AtomicOrdering::Relaxed);
        assert!(batches <= 80, "batching never engaged: {batches}");
        svc.shutdown();
    }

    #[test]
    fn predictions_match_direct_model_calls() {
        let model = fitted_toy();
        let svc = PredictionService::start(model.clone(), None, ServiceConfig::default());
        for x in [vec![1.0, 1.0], vec![4.0, 2.0], vec![3.0, 5.5]] {
            let served = svc.predict(x.clone()).unwrap();
            let (m, v) = model.predict_latent(&x);
            assert!((served.latent_mean - m).abs() < 1e-12);
            assert!((served.latent_var - v).abs() < 1e-12);
            assert!((served.probability - class_probability(m, v)).abs() < 1e-12);
        }
        svc.shutdown();
    }

    fn fitted_cs_fic_toy() -> Arc<FittedClassifier> {
        let x = random_points(80, 2, 6.0, 11);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        let model = GpClassifier::new_cs_fic(
            CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
            CovFunction::new(CovKind::Se, 2, 0.6, 3.0),
            8,
        )
        .unwrap();
        Arc::new(model.infer_only(&x, &y).unwrap())
    }

    /// CS+FIC fits take the runtime's batched probit stage like sparse
    /// fits: the service is started *with* an artifact directory (the
    /// runtime falls back to its native interpreter when no manifest is
    /// present), so the probability column flows through
    /// `Runtime::predict_probit` — and must equal the native closed form.
    #[test]
    fn cs_fic_service_batches_probit_through_the_runtime() {
        let model = fitted_cs_fic_toy();
        let svc = PredictionService::start(
            model.clone(),
            Some(std::env::temp_dir().join("csgp-no-artifacts")),
            ServiceConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(5),
                ..ServiceConfig::default()
            },
        );
        for x in [vec![1.0, 1.0], vec![4.0, 2.0], vec![2.5, 5.0]] {
            let served = svc.predict(x.clone()).unwrap();
            let (m, v) = model.predict_latent(&x);
            assert!((served.latent_mean - m).abs() < 1e-12);
            assert!((served.latent_var - v).abs() < 1e-12);
            assert!((served.probability - class_probability(m, v)).abs() < 1e-12);
        }
        svc.shutdown();
    }

    #[test]
    fn shutdown_is_clean_and_idempotent() {
        let svc = PredictionService::start(fitted_toy(), None, ServiceConfig::default());
        svc.shutdown();
        svc.shutdown();
        assert!(svc.predict(vec![0.0, 0.0]).is_err());
    }

    /// Capacity 0 admits nothing: every request is rejected with the
    /// typed `Overloaded` error before touching the queue, and the
    /// rejection counter tracks them.
    #[test]
    fn zero_capacity_rejects_with_backpressure() {
        let svc = PredictionService::start(
            fitted_toy(),
            None,
            ServiceConfig { queue_capacity: 0, ..ServiceConfig::default() },
        );
        for _ in 0..5 {
            let err = svc.predict(vec![1.0, 1.0]).map(|_| ()).unwrap_err();
            assert_eq!(err, ServiceError::Overloaded);
        }
        assert_eq!(svc.stats.rejected.load(AtomicOrdering::Relaxed), 5);
        assert_eq!(svc.stats.requests.load(AtomicOrdering::Relaxed), 0);
        // rejection leaks no slots: raising nothing, in_flight is back to 0
        assert_eq!(svc.stats.in_flight.load(AtomicOrdering::Relaxed), 0);
        svc.shutdown();
    }

    /// The exporter writes an immediate line, periodic lines, and a final
    /// line on stop — all parseable by the trace analyzer, with strictly
    /// increasing `seq` and monotone `t_ns`.
    #[test]
    fn metrics_exporter_round_trips_through_the_analyzer() {
        use crate::obs::profile;
        crate::obs::with_mode(crate::obs::TraceMode::Counters, || {
            let model = fitted_toy();
            let svc = PredictionService::start(model, None, ServiceConfig::default());
            let path = std::env::temp_dir()
                .join(format!("csgp-metrics-unit-{}.jsonl", std::process::id()));
            let exporter = MetricsExporter::start(
                &path,
                Duration::from_millis(5),
                Some(svc.stats.clone()),
            )
            .expect("exporter start");
            for i in 0..20 {
                svc.predict(vec![i as f64 * 0.2, 1.0]).unwrap();
            }
            std::thread::sleep(Duration::from_millis(40));
            exporter.stop();
            svc.shutdown();
            let text = std::fs::read_to_string(&path).expect("metrics file");
            let _ = std::fs::remove_file(&path);
            let data = profile::parse_trace(&text).expect("every line parses");
            assert!(data.metrics.len() >= 3, "immediate + periodic + final lines");
            assert_eq!(data.skipped, 0);
            for w in data.metrics.windows(2) {
                assert!(w[1].seq > w[0].seq, "seq strictly increasing");
                assert!(w[1].t_ns >= w[0].t_ns, "t_ns monotone");
            }
            let last = data.metrics.last().unwrap();
            assert_eq!(last.requests, 20);
            assert_eq!(last.in_flight, 0, "all requests answered before stop");
            let prof = profile::Profile::from_trace(&data);
            let m = prof.metrics.expect("metrics profile");
            assert!(m.monotone);
            assert_eq!(m.requests_delta, 20);
        });
    }

    /// `flush_all_exporters` reaches exporters it does not own — the
    /// SIGINT path — and writes a marked final snapshot.
    #[test]
    fn flush_all_exporters_writes_a_final_snapshot() {
        crate::obs::with_mode(crate::obs::TraceMode::Counters, || {
            let path = std::env::temp_dir()
                .join(format!("csgp-metrics-flush-{}.jsonl", std::process::id()));
            let exporter =
                MetricsExporter::start(&path, Duration::from_secs(3600), None).unwrap();
            flush_all_exporters();
            let text = std::fs::read_to_string(&path).unwrap();
            assert!(text.lines().count() >= 2, "start line + flushed line");
            assert!(text.lines().last().unwrap().contains("\"final\":true"));
            drop(exporter);
            let _ = std::fs::remove_file(&path);
        });
    }

    #[test]
    fn latency_percentiles_are_sampled() {
        let model = fitted_toy();
        let svc = PredictionService::start(model, None, ServiceConfig::default());
        assert!(svc.stats.request_latency_stats().is_none());
        for i in 0..12 {
            svc.predict(vec![i as f64 * 0.3, 1.0]).unwrap();
        }
        let req = svc.stats.request_latency_stats().expect("request samples");
        assert_eq!(req.iters, 12);
        assert!(req.p50 <= req.p90 && req.p90 <= req.p99);
        let bat = svc.stats.batch_latency_stats().expect("batch samples");
        assert!(bat.iters >= 1);
        assert!(bat.p99 >= bat.p50);
        svc.shutdown();
    }
}
