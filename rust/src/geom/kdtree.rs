//! kd-tree for radius queries in moderate-to-high dimension.
//!
//! The grid cell list degrades as the dimension grows (cell occupancy
//! drops, the scan window blows up as `3^D`), so above `D = 3` the
//! [`NeighborIndex`](crate::geom::NeighborIndex) switches to this balanced
//! kd-tree. Built once per point set in `O(n log² n)`; radius queries
//! prune subtrees by the splitting-plane distance and are allocation-free
//! (recursion depth is `O(log n)` thanks to the median split).

/// One tree node: a splitting point plus children. `usize::MAX` marks a
/// missing child.
#[derive(Clone, Debug)]
struct Node {
    /// Index of the splitting point in the original point set.
    point: usize,
    axis: usize,
    left: usize,
    right: usize,
}

const NONE: usize = usize::MAX;

/// Balanced kd-tree over a fixed point set.
#[derive(Clone, Debug)]
pub struct KdTree {
    points: Vec<Vec<f64>>,
    dim: usize,
    nodes: Vec<Node>,
    root: usize,
}

impl KdTree {
    pub fn build(x: &[Vec<f64>]) -> KdTree {
        let dim = x.first().map(|p| p.len()).unwrap_or(0);
        let mut tree = KdTree {
            points: x.to_vec(),
            dim,
            nodes: Vec::with_capacity(x.len()),
            root: NONE,
        };
        let mut idx: Vec<usize> = (0..x.len()).collect();
        tree.root = tree.build_rec(&mut idx, 0);
        tree
    }

    fn build_rec(&mut self, idx: &mut [usize], depth: usize) -> usize {
        if idx.is_empty() {
            return NONE;
        }
        let axis = if self.dim == 0 { 0 } else { depth % self.dim };
        idx.sort_unstable_by(|&a, &b| {
            self.points[a][axis]
                .partial_cmp(&self.points[b][axis])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mid = idx.len() / 2;
        let point = idx[mid];
        let node_id = self.nodes.len();
        self.nodes.push(Node { point, axis, left: NONE, right: NONE });
        // recurse on copies of the two halves (idx is borrowed mutably)
        let mut left_idx: Vec<usize> = idx[..mid].to_vec();
        let mut right_idx: Vec<usize> = idx[mid + 1..].to_vec();
        let left = self.build_rec(&mut left_idx, depth + 1);
        let right = self.build_rec(&mut right_idx, depth + 1);
        self.nodes[node_id].left = left;
        self.nodes[node_id].right = right;
        node_id
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Indices of all points with Euclidean distance <= `radius` from `q`
    /// (inclusive). Results are appended to `out` unsorted.
    ///
    /// Recursive and allocation-free: the tree is median-split at build
    /// time, so the depth is `O(log n)` regardless of the input geometry.
    pub fn neighbors_within(&self, q: &[f64], radius: f64, out: &mut Vec<usize>) {
        if self.root == NONE || radius < 0.0 {
            return;
        }
        self.search(self.root, q, radius * radius, out);
    }

    fn search(&self, id: usize, q: &[f64], r2: f64, out: &mut Vec<usize>) {
        let node = &self.nodes[id];
        let p = &self.points[node.point];
        let mut d2 = 0.0;
        for d in 0..self.dim {
            let diff = p[d] - q[d];
            d2 += diff * diff;
        }
        if d2 <= r2 {
            out.push(node.point);
        }
        let delta = q[node.axis] - p[node.axis];
        let (near, far) = if delta < 0.0 {
            (node.left, node.right)
        } else {
            (node.right, node.left)
        };
        if near != NONE {
            self.search(near, q, r2, out);
        }
        if far != NONE && delta * delta <= r2 {
            self.search(far, q, r2, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_points;

    fn brute(x: &[Vec<f64>], q: &[f64], r: f64) -> Vec<usize> {
        let mut out: Vec<usize> = (0..x.len())
            .filter(|&i| {
                let d2: f64 = x[i].iter().zip(q).map(|(a, b)| (a - b) * (a - b)).sum();
                d2 <= r * r
            })
            .collect();
        out.sort_unstable();
        out
    }

    #[test]
    fn matches_brute_force_across_dims() {
        for dim in [1usize, 2, 4, 6, 10] {
            let x = random_points(150, dim, 6.0, 100 + dim as u64);
            let t = KdTree::build(&x);
            for (qi, r) in [(0usize, 1.0), (5, 2.5), (9, 6.0), (17, 0.0), (33, 50.0)] {
                let mut got = Vec::new();
                t.neighbors_within(&x[qi], r, &mut got);
                got.sort_unstable();
                assert_eq!(got, brute(&x, &x[qi], r), "dim {dim} q {qi} r {r}");
            }
        }
    }

    #[test]
    fn off_sample_queries_work() {
        let x = random_points(80, 3, 4.0, 77);
        let t = KdTree::build(&x);
        let q = vec![2.0, 2.0, 2.0];
        let mut got = Vec::new();
        t.neighbors_within(&q, 1.7, &mut got);
        got.sort_unstable();
        assert_eq!(got, brute(&x, &q, 1.7));
    }

    #[test]
    fn duplicates_all_returned() {
        let mut x = random_points(10, 4, 3.0, 5);
        x.push(x[2].clone());
        x.push(x[2].clone());
        let t = KdTree::build(&x);
        let mut got = Vec::new();
        t.neighbors_within(&x[2], 0.0, &mut got);
        got.sort_unstable();
        assert_eq!(got, vec![2, 10, 11]);
    }

    #[test]
    fn empty_tree() {
        let t = KdTree::build(&[]);
        let mut out = Vec::new();
        t.neighbors_within(&[1.0], 5.0, &mut out);
        assert!(out.is_empty());
    }
}
