//! Spatial neighbor indices for compactly supported covariance assembly.
//!
//! A CS covariance `k_pp,q` vanishes exactly when the ARD-scaled distance
//! `r = sqrt(Σ_d Δ_d²/l_d²)` reaches 1, so entry `(i, j)` of the Gram
//! matrix can only be nonzero when the *Euclidean* distance satisfies
//! `‖x_i − x_j‖ < max_d l_d`. Assembly therefore reduces to a
//! radius-`max(lengthscales)` neighbor query per column followed by the
//! exact `r < 1` filter — `O(n·k)` for `k` average neighbors instead of
//! the `O(n²)` all-pairs scan (cf. Barber 2020, sparse GPs via CS-kernel
//! families).
//!
//! Two backends, selected automatically by input dimension:
//!
//! * [`GridIndex`] — uniform cell list; the right structure for the
//!   paper's low-D geometric data (`D <= 3`).
//! * [`KdTree`] — balanced kd-tree for higher dimensions where grid cells
//!   are mostly empty.
//!
//! Both answer *inclusive* `dist <= radius` queries and may over-return
//! (callers re-check the exact kernel condition), so the assembled pattern
//! and values are bit-identical to the brute-force path.

pub mod grid;
pub mod kdtree;

pub use grid::GridIndex;
pub use kdtree::KdTree;

/// Input dimension above which [`NeighborIndex::build`] switches from the
/// grid cell list to the kd-tree.
pub const GRID_MAX_DIM: usize = 3;

/// A radius-query index over a fixed point set.
#[derive(Clone, Debug)]
pub enum NeighborIndex {
    Grid(GridIndex),
    KdTree(KdTree),
}

impl NeighborIndex {
    /// Build the index, auto-selecting the backend by dimension.
    /// `radius_hint` sizes the grid cells (typically the covariance
    /// support radius); queries may use any radius afterwards.
    pub fn build(x: &[Vec<f64>], radius_hint: f64) -> NeighborIndex {
        let dim = x.first().map(|p| p.len()).unwrap_or(0);
        if dim <= GRID_MAX_DIM {
            NeighborIndex::Grid(GridIndex::build(x, radius_hint))
        } else {
            NeighborIndex::KdTree(KdTree::build(x))
        }
    }

    /// Force the grid backend (tests / benchmarks).
    pub fn grid(x: &[Vec<f64>], cell: f64) -> NeighborIndex {
        NeighborIndex::Grid(GridIndex::build(x, cell))
    }

    /// Force the kd-tree backend (tests / benchmarks).
    pub fn kdtree(x: &[Vec<f64>]) -> NeighborIndex {
        NeighborIndex::KdTree(KdTree::build(x))
    }

    pub fn len(&self) -> usize {
        match self {
            NeighborIndex::Grid(g) => g.len(),
            NeighborIndex::KdTree(t) => t.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        match self {
            NeighborIndex::Grid(g) => g.dim(),
            NeighborIndex::KdTree(t) => t.dim(),
        }
    }

    /// Append the indices of all points with `‖p − q‖ <= radius`
    /// (inclusive, unsorted) to `out`.
    pub fn neighbors_within(&self, q: &[f64], radius: f64, out: &mut Vec<usize>) {
        match self {
            NeighborIndex::Grid(g) => g.neighbors_within(q, radius, out),
            NeighborIndex::KdTree(t) => t.neighbors_within(q, radius, out),
        }
    }

    /// Like [`neighbors_within`](Self::neighbors_within) but clears `out`
    /// first and returns it sorted ascending — the form covariance
    /// assembly wants (CSC columns keep sorted row indices).
    pub fn neighbors_sorted(&self, q: &[f64], radius: f64, out: &mut Vec<usize>) {
        out.clear();
        self.neighbors_within(q, radius, out);
        out.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_points;

    #[test]
    fn auto_selects_backend_by_dim() {
        let x2 = random_points(10, 2, 5.0, 1);
        let x5 = random_points(10, 5, 5.0, 2);
        assert!(matches!(NeighborIndex::build(&x2, 1.0), NeighborIndex::Grid(_)));
        assert!(matches!(NeighborIndex::build(&x5, 1.0), NeighborIndex::KdTree(_)));
    }

    #[test]
    fn backends_agree_with_each_other() {
        for dim in [1usize, 2, 3] {
            let x = random_points(200, dim, 7.0, 40 + dim as u64);
            let g = NeighborIndex::grid(&x, 1.2);
            let t = NeighborIndex::kdtree(&x);
            let mut a = Vec::new();
            let mut b = Vec::new();
            for qi in (0..x.len()).step_by(17) {
                for r in [0.4, 1.2, 3.3] {
                    g.neighbors_sorted(&x[qi], r, &mut a);
                    t.neighbors_sorted(&x[qi], r, &mut b);
                    assert_eq!(a, b, "dim {dim} q {qi} r {r}");
                }
            }
        }
    }
}
