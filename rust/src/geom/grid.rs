//! Uniform grid (cell list) for low-dimensional radius queries.
//!
//! Points are binned into axis-aligned cubic cells of a fixed size chosen
//! at build time (normally the covariance support radius). A radius-`r`
//! query visits only the cells intersecting the query ball, so for the
//! paper's geometric point sets the cost per query is `O(k)` in the number
//! of returned candidates rather than `O(n)`.
//!
//! The cell size is fixed at build time but queries accept *any* radius:
//! the scan range adapts, so a single grid serves a whole hyperparameter
//! search even as the support radius moves. When the requested radius is
//! much larger than the cell size the query switches to iterating the
//! occupied cells directly (never slower than a constant factor over the
//! brute-force scan).

use std::collections::HashMap;

/// Dimensions up to which the query's cell-window scratch lives on the
/// stack (queries stay allocation-free).
pub const GRID_STACK_DIM: usize = 16;

/// Cell-list spatial index over a fixed point set.
#[derive(Clone, Debug)]
pub struct GridIndex {
    points: Vec<Vec<f64>>,
    dim: usize,
    cell: f64,
    mins: Vec<f64>,
    /// Occupied cells only: integer cell coordinates -> point indices.
    cells: HashMap<Vec<i64>, Vec<u32>>,
}

impl GridIndex {
    /// Build with the given cell size (clamped to a sane positive value).
    pub fn build(x: &[Vec<f64>], cell: f64) -> GridIndex {
        let dim = x.first().map(|p| p.len()).unwrap_or(0);
        let mut mins = vec![0.0; dim];
        let mut maxs = vec![0.0; dim];
        for d in 0..dim {
            mins[d] = x.iter().map(|p| p[d]).fold(f64::INFINITY, f64::min);
            maxs[d] = x.iter().map(|p| p[d]).fold(f64::NEG_INFINITY, f64::max);
        }
        let extent = (0..dim).map(|d| maxs[d] - mins[d]).fold(0.0f64, f64::max);
        let mut cell = if cell.is_finite() && cell > 0.0 { cell } else { 1.0 };
        // keep the grid resolution bounded so the worst-case number of
        // distinct cell keys stays manageable
        if extent > 0.0 {
            cell = cell.max(extent / 1024.0);
        }
        let mut cells: HashMap<Vec<i64>, Vec<u32>> = HashMap::new();
        let mut key = vec![0i64; dim];
        for (i, p) in x.iter().enumerate() {
            for d in 0..dim {
                key[d] = ((p[d] - mins[d]) / cell).floor() as i64;
            }
            cells.entry(key.clone()).or_default().push(i as u32);
        }
        GridIndex { points: x.to_vec(), dim, cell, mins, cells }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Indices of all points with Euclidean distance <= `radius` from `q`
    /// (inclusive; the query point's own index is included if it is in the
    /// set). Results are appended to `out` unsorted.
    ///
    /// Queries on the serving hot path must not allocate: the cell window
    /// lives on the stack up to [`GRID_STACK_DIM`] dimensions (the grid is
    /// the low-D backend, so this covers every real caller) and falls back
    /// to heap scratch above that.
    pub fn neighbors_within(&self, q: &[f64], radius: f64, out: &mut Vec<usize>) {
        if self.points.is_empty() || radius < 0.0 {
            return;
        }
        if self.dim <= GRID_STACK_DIM {
            let mut lo = [0i64; GRID_STACK_DIM];
            let mut hi = [0i64; GRID_STACK_DIM];
            let mut key = [0i64; GRID_STACK_DIM];
            let d = self.dim;
            self.query_window(q, radius, &mut lo[..d], &mut hi[..d], &mut key[..d], out);
        } else {
            let mut lo = vec![0i64; self.dim];
            let mut hi = vec![0i64; self.dim];
            let mut key = vec![0i64; self.dim];
            self.query_window(q, radius, &mut lo, &mut hi, &mut key, out);
        }
    }

    fn query_window(
        &self,
        q: &[f64],
        radius: f64,
        lo: &mut [i64],
        hi: &mut [i64],
        key: &mut [i64],
        out: &mut Vec<usize>,
    ) {
        let r2 = radius * radius;
        // cell-coordinate window intersecting the query ball
        let mut window: u64 = 1;
        for d in 0..self.dim {
            lo[d] = ((q[d] - radius - self.mins[d]) / self.cell).floor() as i64;
            hi[d] = ((q[d] + radius - self.mins[d]) / self.cell).floor() as i64;
            window = window.saturating_mul((hi[d] - lo[d] + 1) as u64);
        }
        if window as usize > 4 * self.cells.len().max(1) {
            // radius much larger than the cell size: walking the window
            // would touch mostly-empty keys, so scan occupied cells instead
            for (ckey, pts) in self.cells.iter() {
                if (0..self.dim).any(|d| ckey[d] < lo[d] || ckey[d] > hi[d]) {
                    continue;
                }
                self.scan_cell(pts, q, r2, out);
            }
            return;
        }
        // odometer over the (small) cell window
        key.copy_from_slice(lo);
        loop {
            // Vec<i64> keys borrow-match against &[i64]
            if let Some(pts) = self.cells.get(&*key) {
                self.scan_cell(pts, q, r2, out);
            }
            // increment
            let mut d = 0;
            loop {
                if d == self.dim {
                    return;
                }
                key[d] += 1;
                if key[d] <= hi[d] {
                    break;
                }
                key[d] = lo[d];
                d += 1;
            }
        }
    }

    fn scan_cell(&self, pts: &[u32], q: &[f64], r2: f64, out: &mut Vec<usize>) {
        for &i in pts {
            let p = &self.points[i as usize];
            let mut d2 = 0.0;
            for d in 0..self.dim {
                let diff = p[d] - q[d];
                d2 += diff * diff;
            }
            if d2 <= r2 {
                out.push(i as usize);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_points;

    fn brute(x: &[Vec<f64>], q: &[f64], r: f64) -> Vec<usize> {
        let mut out: Vec<usize> = (0..x.len())
            .filter(|&i| {
                let d2: f64 = x[i].iter().zip(q).map(|(a, b)| (a - b) * (a - b)).sum();
                d2 <= r * r
            })
            .collect();
        out.sort_unstable();
        out
    }

    #[test]
    fn matches_brute_force_at_many_radii() {
        for dim in [1usize, 2, 3] {
            let x = random_points(120, dim, 8.0, dim as u64 + 3);
            let g = GridIndex::build(&x, 1.5);
            for (qi, r) in [(0usize, 0.5), (3, 1.5), (7, 3.0), (11, 20.0), (13, 0.0)] {
                let mut got = Vec::new();
                g.neighbors_within(&x[qi], r, &mut got);
                got.sort_unstable();
                assert_eq!(got, brute(&x, &x[qi], r), "dim {dim} q {qi} r {r}");
            }
        }
    }

    #[test]
    fn includes_self_and_duplicates() {
        let mut x = random_points(20, 2, 5.0, 9);
        x.push(x[4].clone()); // exact duplicate
        let g = GridIndex::build(&x, 1.0);
        let mut out = Vec::new();
        g.neighbors_within(&x[4], 0.0, &mut out);
        out.sort_unstable();
        assert!(out.contains(&4) && out.contains(&20), "{out:?}");
    }

    #[test]
    fn empty_set_is_fine() {
        let g = GridIndex::build(&[], 1.0);
        let mut out = Vec::new();
        g.neighbors_within(&[0.0, 0.0], 1.0, &mut out);
        assert!(out.is_empty());
        assert!(g.is_empty());
    }
}
