//! Lightweight instrumentation: named counters and accumulated timers.
//!
//! The EP hot loop is instrumented with [`Metrics::time`] sections so the perf pass
//! (EXPERIMENTS.md §Perf) can attribute time to `rowmod`, `solve_t`,
//! `moments`, etc. without an external profiler. Overhead is one `Instant`
//! pair per section; disabled sections cost a branch.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A registry of accumulated section timings and counters.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    timings: BTreeMap<&'static str, (Duration, u64)>,
    counters: BTreeMap<&'static str, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Time a closure under `name`.
    pub fn time<T>(&self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add_time(name, t0.elapsed());
        out
    }

    pub fn add_time(&self, name: &'static str, d: Duration) {
        let mut g = self.inner.lock().unwrap();
        let e = g.timings.entry(name).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    pub fn incr(&self, name: &'static str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name).or_insert(0) += by;
    }

    pub fn total(&self, name: &'static str) -> Duration {
        self.inner.lock().unwrap().timings.get(name).map(|e| e.0).unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, name: &'static str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.timings.clear();
        g.counters.clear();
    }

    /// Render a sorted report, longest sections first.
    pub fn report(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut rows: Vec<_> = g.timings.iter().collect();
        rows.sort_by(|a, b| b.1 .0.cmp(&a.1 .0));
        let mut out = String::new();
        for (name, (dur, calls)) in rows {
            out.push_str(&format!(
                "{name:<24} {:>10.3} ms  ({calls} calls, {:.3} µs/call)\n",
                dur.as_secs_f64() * 1e3,
                dur.as_secs_f64() * 1e6 / (*calls).max(1) as f64
            ));
        }
        for (name, v) in g.counters.iter() {
            out.push_str(&format!("{name:<24} {v:>10} (count)\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_accumulates() {
        let m = Metrics::new();
        let x = m.time("work", || 21 * 2);
        assert_eq!(x, 42);
        m.time("work", || ());
        assert!(m.total("work") > Duration::ZERO);
        let report = m.report();
        assert!(report.contains("work"));
        assert!(report.contains("2 calls"));
    }

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.incr("sites", 5);
        m.incr("sites", 2);
        assert_eq!(m.count("sites"), 7);
        m.reset();
        assert_eq!(m.count("sites"), 0);
    }
}
