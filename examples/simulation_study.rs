//! End-to-end driver (EXPERIMENTS.md §E2E): the full system on the
//! paper's §6.1 simulation workload, exercising every layer —
//!
//!   L1/L2  covariance assembly through the artifact runtime (native
//!          interpreter by default; PJRT behind `--features xla`),
//!   L3     sparse EP (Algorithm 1: rowmod + sparse solves) with MAP-II
//!          hyperparameter optimization (SCG + Takahashi gradients),
//!   serve  batched prediction through the coordinator with the
//!          `predict_probit` stage on the response path,
//!
//! and compares against the dense k_se baseline on the same split.
//!
//! Run: `cargo run --release --example simulation_study`

use std::sync::Arc;
use std::time::Instant;

use csgp::coordinator::{PredictionService, ServiceConfig};
use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::model::{GpClassifier, Inference};
use csgp::gp::predict::evaluate;
use csgp::runtime::Runtime;
use csgp::sparse::ordering::Ordering;

fn main() {
    let n_train = std::env::var("CSGP_N").ok().and_then(|s| s.parse().ok()).unwrap_or(1000);
    let n_test = 500;
    let data = cluster_dataset(&ClusterConfig::paper_2d(n_train + n_test), 42);
    let (train, test) = data.split(n_train);
    println!("== E2E simulation study: n_train = {n_train}, n_test = {n_test}, 2-D cluster data ==");

    // --- L1/L2: covariance assembly through the artifact runtime ---------
    let rt = Runtime::open_default().expect("runtime open");
    println!("runtime backend: {}", rt.platform());
    let cov0 = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.3);
    let t0 = Instant::now();
    let k_rt = rt.cov_matrix(&cov0, &train.x).expect("runtime covariance assembly");
    let t_asm = t0.elapsed();
    // brute force is an independent path from the runtime's index-backed
    // assembly, so the agreement figure is a real cross-check
    let k_ref = cov0.cov_matrix_brute(&train.x);
    assert_eq!(k_rt.col_ptr, k_ref.col_ptr, "assembly pattern mismatch vs brute force");
    let max_diff = k_rt
        .values
        .iter()
        .zip(&k_ref.values)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!(
        "covariance via {}: {} nnz in {:?} (brute-force agreement {max_diff:.1e})",
        rt.platform(),
        k_rt.nnz(),
        t_asm
    );

    // --- L3: sparse EP + hyperparameter optimization ----------------------
    let mut sparse_model = GpClassifier::new(cov0.clone(), Inference::Sparse(Ordering::Rcm));
    sparse_model.opt_opts.max_iters = 8;
    let t0 = Instant::now();
    let sparse_fit = sparse_model.fit(&train.x, &train.y).expect("sparse EP fit");
    let t_sparse_fit = t0.elapsed();
    println!(
        "sparse EP (pp3): opt {:?} ({} iters), EP run {:?}, fill-K {:.1}% fill-L {:.1}%, logZ {:.2}",
        sparse_fit.report.opt_time,
        sparse_fit.report.opt_iters,
        sparse_fit.report.ep_time,
        100.0 * sparse_fit.report.fill_k,
        100.0 * sparse_fit.report.fill_l,
        sparse_fit.report.log_z
    );

    // --- baseline: dense EP with k_se (no optimization; timing only) -----
    let dense_model =
        GpClassifier::new(CovFunction::new(CovKind::Se, 2, 1.0, 1.3), Inference::Dense);
    let t0 = Instant::now();
    let dense_fit = dense_model.infer_only(&train.x, &train.y).expect("dense EP");
    let t_dense = t0.elapsed();
    println!(
        "dense EP (se):   EP run {t_dense:?}  |  sparse/dense EP-run speedup: {:.1}x",
        t_dense.as_secs_f64() / sparse_fit.report.ep_time.as_secs_f64()
    );

    // --- quality ----------------------------------------------------------
    let m_sparse = evaluate(&sparse_fit.predict_latent_batch(&test.x), &test.y);
    let m_dense = evaluate(&dense_fit.predict_latent_batch(&test.x), &test.y);
    println!(
        "test metrics: pp3-sparse err {:.3} / nlpd {:.3}   se-dense err {:.3} / nlpd {:.3}",
        m_sparse.err, m_sparse.nlpd, m_dense.err, m_dense.nlpd
    );

    // --- serving: batched prediction through the coordinator --------------
    let artifact_dir = std::path::PathBuf::from(
        std::env::var("CSGP_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    );
    let svc = Arc::new(PredictionService::start(
        Arc::new(sparse_fit),
        Some(artifact_dir),
        ServiceConfig::default(),
    ));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for chunk in test.x.chunks(test.x.len() / 4 + 1) {
        let chunk = chunk.to_vec();
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            chunk.iter().map(|x| svc.predict(x.clone()).unwrap()).collect::<Vec<_>>()
        }));
    }
    let mut served = Vec::new();
    for h in handles {
        served.extend(h.join().unwrap());
    }
    let wall = t0.elapsed();
    let correct = served
        .iter()
        .zip(&test.y)
        .filter(|(p, &y)| (p.probability - 0.5).signum() == y)
        .count();
    println!(
        "served {} predictions in {:?} ({:.0} req/s), batches up to {}, accuracy {:.3}",
        served.len(),
        wall,
        served.len() as f64 / wall.as_secs_f64(),
        svc.stats.batched_items_max.load(std::sync::atomic::Ordering::Relaxed),
        correct as f64 / served.len() as f64
    );
    svc.shutdown();

    let _ = t_sparse_fit;
    assert!(m_sparse.err < 0.35, "E2E quality regression");
    println!("== E2E OK ==");
}
