//! Quickstart: train a sparse-EP GP classifier with a compactly supported
//! covariance on a small 2-D problem, optimize the hyperparameters, and
//! predict.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Pass `--trace [path]` to record a full span trace of the fit to a
//! JSONL file (default `trace.jsonl`) — the CI trace-schema smoke runs
//! exactly this.

use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::model::{GpClassifier, Inference};
use csgp::sparse::ordering::Ordering;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let trace_path = args.iter().position(|a| a == "--trace").map(|i| {
        args.get(i + 1)
            .filter(|v| !v.starts_with("--"))
            .map(String::as_str)
            .unwrap_or("trace.jsonl")
            .to_string()
    });
    if let Some(path) = &trace_path {
        csgp::obs::set_mode(csgp::obs::TraceMode::Full);
        csgp::obs::set_sink(path).expect("cannot open trace sink");
        eprintln!("tracing to {path}");
    }
    // 1. data: the paper's nearest-centre cluster workload, 2-D
    let data = cluster_dataset(&ClusterConfig::paper_2d(600), 1);
    let (train, test) = data.split(400);

    // 2. model: k_pp3 compactly supported covariance + the paper's sparse
    //    EP (Algorithm 1); Ordering::Auto picks the fill-reducing
    //    ordering from the pattern (RCM / quotient min-degree / nested
    //    dissection — see sparse::ordering)
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.5);
    let mut model = GpClassifier::new(cov, Inference::Sparse(Ordering::Auto));
    model.opt_opts.max_iters = 10; // quick MAP-II search

    // 3. fit (optimizes [ln σ², ln l..] against logZ_EP + half-Student-t prior)
    let fitted = model.fit(&train.x, &train.y).expect("EP failed");
    println!(
        "fitted: σ² = {:.3}, l = {:.3} | logZ = {:.2} | fill-K = {:.1}% fill-L = {:.1}%",
        fitted.cov.sigma2,
        fitted.cov.lengthscales[0],
        fitted.report.log_z,
        100.0 * fitted.report.fill_k,
        100.0 * fitted.report.fill_l,
    );
    println!(
        "hyperparameter optimization: {:?} ({} SCG iterations); single EP run: {:?}",
        fitted.report.opt_time, fitted.report.opt_iters, fitted.report.ep_time
    );

    // 4. predict
    let metrics = fitted.evaluate(&test.x, &test.y);
    println!("test error = {:.3}, nlpd = {:.3} on {} points", metrics.err, metrics.nlpd, metrics.n);
    let probs = fitted.predict_proba(&test.x[..5]);
    println!("first five class probabilities: {probs:.3?}");
    assert!(metrics.err < 0.4, "quickstart model should beat chance comfortably");

    if trace_path.is_some() {
        let n = csgp::obs::flush().expect("trace flush failed");
        eprintln!("{}", csgp::obs::summary());
        eprintln!("flushed {n} trace spans");
    }
}
