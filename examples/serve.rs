//! Serving demo: fit a sparse-EP classifier, run the coordinator's
//! batching prediction service under concurrent client load, and report
//! throughput + latency percentiles (the serving story for a trained GP
//! classifier, with the probit stage on the XLA artifact when available).
//!
//! Run: `cargo run --release --example serve [-- <requests>]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use csgp::coordinator::{PredictionService, ServiceConfig};
use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::model::{GpClassifier, Inference};
use csgp::rng::Rng;
use csgp::sparse::ordering::Ordering;

fn main() {
    let requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4000);
    let data = cluster_dataset(&ClusterConfig::paper_2d(800), 7);
    let model = GpClassifier::new(
        CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.3),
        Inference::Sparse(Ordering::Rcm),
    );
    println!("fitting model (n = 800)...");
    let fitted = Arc::new(model.infer_only(&data.x, &data.y).unwrap());

    let artifact_dir = std::path::PathBuf::from(
        std::env::var("CSGP_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    );
    let use_xla = artifact_dir.join("manifest.json").exists();
    println!("probit stage: {}", if use_xla { "XLA artifact" } else { "native (no artifacts)" });
    println!(
        "latent stage: worker pool, {} threads (CSGP_THREADS to override)",
        csgp::par::default_threads()
    );

    for (clients, batch) in [(1usize, 1usize), (4, 64), (16, 256)] {
        let svc = Arc::new(PredictionService::start(
            fitted.clone(),
            use_xla.then(|| artifact_dir.clone()),
            ServiceConfig {
                max_batch: batch,
                max_wait: Duration::from_millis(2),
                ..ServiceConfig::default()
            },
        ));
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..clients {
            let svc = svc.clone();
            let per = requests / clients;
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(c as u64 + 1);
                let mut lat = Vec::with_capacity(per);
                for _ in 0..per {
                    let x = vec![rng.uniform_in(0.0, 10.0), rng.uniform_in(0.0, 10.0)];
                    lat.push(svc.predict(x).unwrap().service_time);
                }
                lat
            }));
        }
        let mut lats: Vec<Duration> = Vec::new();
        for h in handles {
            lats.extend(h.join().unwrap());
        }
        let wall = t0.elapsed();
        lats.sort();
        let n = lats.len();
        println!(
            "clients={clients:>2} max_batch={batch:>3}: {:>7.0} req/s | p50 {:>9?} p95 {:>9?} p99 {:>9?} | biggest batch {}",
            n as f64 / wall.as_secs_f64(),
            lats[n / 2],
            lats[n * 95 / 100],
            lats[n * 99 / 100],
            svc.stats.batched_items_max.load(std::sync::atomic::Ordering::Relaxed)
        );
        svc.shutdown();
    }
}
