//! UCI-analogue benchmark: trains k_se (dense EP), k_pp3 (sparse EP),
//! FIC and the CS+FIC hybrid (local pp3 + global SE through inducing
//! points) on two of the paper's §6.2 datasets through the coordinator's
//! job manager, then cross-validates the winner.
//!
//! Run: `cargo run --release --example uci_benchmark`

use std::time::Duration;

use csgp::coordinator::{JobManager, JobStatus, TrainSpec};
use csgp::data::cv::cross_validate;
use csgp::data::uci::{generate, UCI_SPECS};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::model::{GpClassifier, Inference};
use csgp::sparse::ordering::Ordering;

fn main() {
    // crabs (200/6) and sonar (208/60) — the paper's smallest and widest
    let specs: Vec<_> =
        UCI_SPECS.iter().filter(|s| s.name == "crabs" || s.name == "sonar").collect();
    let mgr = JobManager::start(3);

    println!("submitting {} training jobs to the coordinator...", specs.len() * 4);
    let mut jobs = Vec::new();
    for spec in &specs {
        let data = generate(spec, 11);
        for (label, cov, global_cov, inference) in [
            (
                "k_se/dense",
                CovFunction::new(CovKind::Se, spec.d, 1.0, 2.5),
                None,
                Inference::Dense,
            ),
            (
                "k_pp3/sparse",
                CovFunction::new(CovKind::Pp(3), spec.d, 1.0, 4.0),
                None,
                Inference::Sparse(Ordering::Rcm),
            ),
            (
                "FIC m=10",
                CovFunction::new(CovKind::Se, spec.d, 1.0, 2.5),
                None,
                Inference::Fic { m: 10 },
            ),
            (
                "CS+FIC m=10",
                CovFunction::new(CovKind::Pp(3), spec.d, 1.0, 4.0),
                Some(CovFunction::new(CovKind::Se, spec.d, 0.8, 2.5)),
                Inference::CsFic { m: 10, ordering: Ordering::Auto },
            ),
        ] {
            let id = mgr
                .submit(TrainSpec {
                    dataset: data.clone(),
                    cov,
                    global_cov,
                    inference,
                    optimize: false,
                    snapshot_save: None,
                })
                .unwrap();
            jobs.push((spec.name, label, id));
        }
    }

    println!("\n| dataset | model | status | logZ | EP time |");
    println!("|---|---|---|---|---|");
    for (ds, label, id) in &jobs {
        match mgr.wait(*id, Duration::from_secs(300)) {
            Some(JobStatus::Done { log_post, ep_time, .. }) => {
                println!("| {ds} | {label} | done | {log_post:.2} | {ep_time:?} |");
            }
            other => println!("| {ds} | {label} | {other:?} | | |"),
        }
    }
    mgr.shutdown();

    // cross-validate the sparse model on crabs
    let crabs = generate(UCI_SPECS.iter().find(|s| s.name == "crabs").unwrap(), 11);
    let model = GpClassifier::new(
        CovFunction::new(CovKind::Pp(3), crabs.dim(), 1.0, 4.0),
        Inference::Sparse(Ordering::Rcm),
    );
    let res = cross_validate(&model, &crabs, 10, false, 3).unwrap();
    println!(
        "\n10-fold CV on crabs (k_pp3 sparse EP): err = {:.3}, nlpd = {:.3}, mean EP {:?}",
        res.err, res.nlpd, res.ep_time
    );
    assert!(res.err < 0.5);
}
