#!/usr/bin/env python3
"""Compare a bench JSON report against a committed baseline.

The perf benches (`cargo bench --bench perf_parallel`, `--bench
perf_serving`, run with CSGP_SMOKE=1 in CI) write flat JSON arrays of
records::

    {"bench": "sweep", "backend": "cs", "n": 600, "threads": 4,
     "ns_per_iter": 123456.0, ...extra fields...}

This script matches every baseline row against the current report by a
configurable key (default: bench, backend, n, threads, k — `k`
participates only when a record carries it, which disambiguates the
serving bench's online_update/cold_refit rows) and fails when

  * a baseline row has no matching current row (a bench silently rotted
    away), or
  * the current value exceeds baseline * (1 + tolerance).

Improvements beyond the tolerance pass, with a note suggesting a
re-seed.  Baselines are committed under benches/baselines/ and are
deliberately seeded on the slow side; tighten them from a trusted run
with `--update`.

Usage:
    bench_check.py [--tolerance 0.25] [--key bench,backend,n,threads,k]
                   [--field ns_per_iter] BASELINE CURRENT
    bench_check.py --update BASELINE CURRENT   # reseed BASELINE from CURRENT
    bench_check.py --self-test                 # verify the gate mechanism

Exit codes: 0 = pass, 1 = regression or missing row, 2 = usage/parse error.
"""

import argparse
import json
import sys

DEFAULT_KEY = "bench,backend,n,threads,k"
DEFAULT_FIELD = "ns_per_iter"
DEFAULT_TOLERANCE = 0.25


def load_rows(path):
    try:
        with open(path) as f:
            rows = json.load(f)
    except OSError as e:
        raise SystemExit(f"bench_check: cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        raise SystemExit(f"bench_check: {path} is not valid JSON: {e}")
    if not isinstance(rows, list):
        raise SystemExit(f"bench_check: {path}: expected a JSON array of records")
    return rows


def row_key(row, key_fields):
    # A field absent from the record contributes None, so records that
    # never carry `k` still key consistently.
    return tuple(row.get(f) for f in key_fields)


def index_rows(rows, key_fields, path):
    out = {}
    for row in rows:
        k = row_key(row, key_fields)
        if k in out:
            raise SystemExit(
                f"bench_check: {path}: duplicate key {fmt_key(k, key_fields)}; "
                f"extend --key to disambiguate"
            )
        out[k] = row
    return out


def fmt_key(key, key_fields):
    parts = [f"{f}={v}" for f, v in zip(key_fields, key) if v is not None]
    return "/".join(parts)


def compare(baseline_rows, current_rows, key_fields, field, tolerance,
            baseline_path="baseline", current_path="current", out=sys.stdout):
    """Returns the number of failures (missing rows + regressions)."""
    base = index_rows(baseline_rows, key_fields, baseline_path)
    cur = index_rows(current_rows, key_fields, current_path)
    failures = 0
    improvements = 0
    print(f"bench_check: {len(base)} baseline row(s), tolerance {tolerance:.0%}", file=out)
    for k, brow in base.items():
        label = fmt_key(k, key_fields)
        if field not in brow:
            print(f"  FAIL  {label}: baseline row has no '{field}' field", file=out)
            failures += 1
            continue
        crow = cur.get(k)
        if crow is None:
            print(f"  FAIL  {label}: missing from {current_path}", file=out)
            failures += 1
            continue
        if field not in crow:
            print(f"  FAIL  {label}: current row has no '{field}' field", file=out)
            failures += 1
            continue
        bv, cv = float(brow[field]), float(crow[field])
        if bv <= 0.0:
            print(f"  FAIL  {label}: non-positive baseline value {bv}", file=out)
            failures += 1
            continue
        ratio = cv / bv
        if ratio > 1.0 + tolerance:
            print(
                f"  FAIL  {label}: {field} {cv:.0f} vs baseline {bv:.0f} "
                f"({ratio:.2f}x, limit {1.0 + tolerance:.2f}x)",
                file=out,
            )
            failures += 1
        elif ratio < 1.0 / (1.0 + tolerance):
            print(
                f"  ok    {label}: {ratio:.2f}x baseline — faster than the seed; "
                f"consider --update to tighten",
                file=out,
            )
            improvements += 1
        else:
            print(f"  ok    {label}: {ratio:.2f}x baseline", file=out)
    verdict = "FAIL" if failures else "PASS"
    print(
        f"bench_check: {verdict} ({failures} failure(s), "
        f"{improvements} improvement(s) beyond tolerance)",
        file=out,
    )
    return failures


def update_baseline(baseline_path, current_rows):
    with open(baseline_path, "w") as f:
        json.dump(current_rows, f, indent=2)
        f.write("\n")
    print(f"bench_check: reseeded {baseline_path} with {len(current_rows)} row(s)")


def self_test():
    """Verify the gate mechanism itself: a deliberate regression must
    fail, a matching run must pass, a vanished row must fail."""
    import io

    key_fields = DEFAULT_KEY.split(",")
    base = [
        {"bench": "sweep", "backend": "cs", "n": 600, "threads": 4, "ns_per_iter": 1000.0},
        {"bench": "online_update", "backend": "sparse", "n": 600, "threads": 4,
         "k": 1, "ns_per_iter": 500.0},
        {"bench": "online_update", "backend": "sparse", "n": 600, "threads": 4,
         "k": 16, "ns_per_iter": 900.0},
    ]

    def run(cur, tol=0.25):
        return compare(base, cur, key_fields, DEFAULT_FIELD, tol, out=io.StringIO())

    checks = []

    # identical run passes
    checks.append(("identical run passes", run(json.loads(json.dumps(base))) == 0))

    # within-tolerance noise passes
    noisy = json.loads(json.dumps(base))
    noisy[0]["ns_per_iter"] = 1200.0  # +20% < 25%
    checks.append(("within-tolerance noise passes", run(noisy) == 0))

    # deliberate regression fails — the property the CI gate exists for
    slow = json.loads(json.dumps(base))
    slow[0]["ns_per_iter"] = 1300.0  # +30% > 25%
    checks.append(("deliberate 30% regression fails", run(slow) == 1))

    # the k-keyed rows regress independently
    slow_k = json.loads(json.dumps(base))
    slow_k[2]["ns_per_iter"] = 2000.0
    checks.append(("k=16 row regresses independently", run(slow_k) == 1))

    # a vanished row fails
    missing = json.loads(json.dumps(base))[:2]
    checks.append(("missing row fails", run(missing) == 1))

    # big improvement still passes
    fast = json.loads(json.dumps(base))
    fast[0]["ns_per_iter"] = 100.0
    checks.append(("improvement passes", run(fast) == 0))

    # tolerance is honoured
    checks.append(("wider tolerance admits the regression", run(slow, tol=0.5) == 0))

    ok = True
    for name, passed in checks:
        print(f"  {'ok' if passed else 'FAIL'}  {name}")
        ok = ok and passed
    print(f"bench_check --self-test: {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("baseline", nargs="?", help="committed baseline JSON")
    ap.add_argument("current", nargs="?", help="freshly generated bench JSON")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed slowdown fraction (default %(default)s)")
    ap.add_argument("--key", default=DEFAULT_KEY,
                    help="comma-separated record fields forming the match key "
                         "(default %(default)s; absent fields match as null)")
    ap.add_argument("--field", default=DEFAULT_FIELD,
                    help="numeric field to compare (default %(default)s)")
    ap.add_argument("--update", action="store_true",
                    help="overwrite BASELINE with CURRENT's rows and exit")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate catches a deliberate regression")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test()
    if not args.baseline or not args.current:
        ap.error("BASELINE and CURRENT are required unless --self-test")
    if args.tolerance <= 0.0:
        ap.error("--tolerance must be positive")

    key_fields = [f.strip() for f in args.key.split(",") if f.strip()]
    if not key_fields:
        ap.error("--key must name at least one field")

    current_rows = load_rows(args.current)
    if args.update:
        update_baseline(args.baseline, current_rows)
        return 0
    baseline_rows = load_rows(args.baseline)
    failures = compare(baseline_rows, current_rows, key_fields, args.field,
                       args.tolerance, args.baseline, args.current)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
