#!/usr/bin/env python3
"""Generate the golden snapshot fixture pinned by the serving test suite.

Writes ``rust/tests/fixtures/golden_dense_v1.snap``: a version-1 dense-backend
snapshot produced *independently* of the Rust writer, byte for byte per the
format documented in ``rust/src/gp/snapshot.rs``. The fixture pins the on-disk
format: if the codec changes without a version bump, loading this file fails
and `golden_fixture_still_loads` (rust/tests/serving.rs) catches it.

The numeric content is a tiny shape-consistent EP state (identity chol(B),
n = 3); it exists to exercise the decoder, not to be a meaningful posterior.

Run from the repo root: python3 tools/make_golden_snapshot.py
"""

import struct
from pathlib import Path

MAGIC = b"CSGPSNAP"
VERSION = 1
TAG_DENSE = 0

buf = bytearray()


def w_u64(v):
    buf.extend(struct.pack("<Q", v))


def w_f64(v):
    buf.extend(struct.pack("<d", float(v)))


def w_bool(v):
    buf.append(1 if v else 0)


def w_f64s(vs):
    w_u64(len(vs))
    for v in vs:
        w_f64(v)


def w_str(s):
    raw = s.encode()
    w_u64(len(raw))
    buf.extend(raw)


def w_points(pts):
    dim = len(pts[0]) if pts else 0
    w_u64(len(pts))
    w_u64(dim)
    for p in pts:
        assert len(p) == dim
        for c in p:
            w_f64(c)


def fnv1a(data):
    h = 0xCBF2_9CE4_8422_2325
    for b in data:
        h = ((h ^ b) * 0x100_0000_01B3) & 0xFFFF_FFFF_FFFF_FFFF
    return h


n = 3

# cov: pp3 in 2-d, sigma2 = 1, lengthscales = [2, 2]
w_str("pp3")
w_u64(2)
w_f64(1.0)
w_f64s([2.0, 2.0])

# training data
w_points([[0.0, 0.0], [1.0, 0.0], [0.0, 1.0]])
w_f64s([1.0, -1.0, 1.0])

# FitReport
w_f64(-2.0)  # log_z
w_f64(-2.0)  # log_post
w_u64(0)  # opt_iters
w_u64(0)  # fn_evals
w_f64(0.0)  # opt_time (s)
w_f64(0.001)  # ep_time (s)
w_f64(1.0)  # fill_k
w_f64(1.0)  # fill_l
w_bool(False)  # opt_converged

# dense backend payload
w_f64s([0.5] * n)  # sites.tau
w_f64s([0.1] * n)  # sites.nu
w_f64s([0.4] * n)  # sites.tau_cav
w_f64s([0.05] * n)  # sites.nu_cav
w_f64s([-0.6] * n)  # sites.ln_zhat
w_f64(-2.0)  # log_z
w_f64s([0.2, -0.2, 0.2])  # mu
w_f64s([0.8] * n)  # sigma_diag
w_u64(5)  # sweeps
w_bool(True)  # converged
w_f64s([0.7] * n)  # sw
w_u64(n)  # chol_b.n
w_f64s([1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0])  # chol_b.l (identity)
w_f64s([0.1, -0.1, 0.1])  # w_pred

payload = bytes(buf)
header = MAGIC + struct.pack("<I", VERSION) + bytes([TAG_DENSE])
header += struct.pack("<Q", len(payload)) + struct.pack("<Q", fnv1a(payload))

out = Path(__file__).resolve().parent.parent / "rust/tests/fixtures/golden_dense_v1.snap"
out.parent.mkdir(parents=True, exist_ok=True)
out.write_bytes(header + payload)
print(f"wrote {out} ({len(header) + len(payload)} bytes)")
